//! The optimization service — Layer 3's front end.
//!
//! The paper's system is a compiler, so the coordinator is the part a
//! downstream user deploys: a threaded service that accepts *optimize*
//! jobs (DSL source + input shapes → enumerate, rank, pick the best
//! rearrangement) and *execute* jobs (run an AOT artifact through the PJRT
//! runtime), with
//!
//! - a bounded intake queue with **admission control**: when the queue is
//!   at capacity new optimize jobs are shed with a typed
//!   [`Error::Overloaded`] carrying the observed depth, instead of
//!   queueing unboundedly and blowing the tail latency of everything
//!   behind them,
//! - **deadline propagation**: a job's [`OptimizeSpec::deadline_ms`] is
//!   measured from *intake*, so time spent queued is charged against the
//!   anytime search budget ([`JobCtl::deadline_origin`]),
//! - **cooperative cancellation**: [`OptimizeHandle::cancel`] flips the
//!   job's [`CancelToken`](crate::enumerate::CancelToken); a queued job is
//!   dropped at worker checkout, a *running* search stops mid-wave,
//! - **compatible-job batching**: workers check out one leader plus any
//!   queued *distinct* jobs of the same kernel family (same generation and
//!   α-invariant source hash) and run them back-to-back, soonest deadline
//!   first, so the family reuses one pooled arena checkout sequentially
//!   (identical jobs never batch — they coalesce onto the in-flight
//!   leader via single-flight instead),
//! - a worker pool for CPU-bound optimization pipelines,
//! - a dedicated runtime thread owning the (non-`Send`) PJRT client, with
//!   an executable cache and request batching,
//! - response routing back to each submitter via per-job channels,
//! - service metrics.
//!
//! The typed front door is [`Coordinator::submit_optimize`], which
//! resolves to [`OptimizeResult`] directly; the enum-shaped
//! [`Coordinator::submit`] delegates to it. Python never appears anywhere
//! here — artifacts were compiled ahead of time by `make artifacts`.

mod metrics;
mod pipeline;

pub use metrics::Metrics;
pub use pipeline::{
    optimize, optimize_ctl, CanonicalKey, ExecRehearsal, JobCtl, OptimizeResult,
    OptimizeSpec, OptimizeSpecBuilder, RankBy, MAX_DEADLINE_MS,
};

use crate::enumerate::CancelToken;
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Best-effort description of a panic payload (the `Box<dyn Any>` a
/// worker catches from a panicking pipeline run).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// One optimize-result cache entry: the report plus the exact source
/// text that produced it, so a later hit can be classified exact
/// (byte-identical resubmission) vs canonical (α-equivalent or
/// reformatted source of the same kernel).
#[derive(Clone)]
struct CacheEntry {
    source: String,
    result: OptimizeResult,
}

/// Shared optimize-path state: the result LRU and the single-flight
/// table, guarded by *one* mutex so hit classification, waiter
/// registration and leader election are a single atomic decision — no
/// interleaving can lose a waiter or elect two leaders for one key.
struct OptShared {
    cache: crate::util::Lru<CanonicalKey, CacheEntry>,
    /// Key → reply senders of jobs coalesced onto the in-flight leader
    /// for that key. An entry exists iff a leader is running; it is
    /// created empty at election and drained (under the same lock) when
    /// the leader publishes its result.
    inflight: HashMap<CanonicalKey, Vec<Sender<Result<OptimizeResult>>>>,
}

/// What a worker decided, under the [`OptShared`] lock, to do with an
/// optimize job. Carries the reply sender back out of the critical
/// section in the branches that still own it (a coalesced waiter's
/// sender moved into the in-flight table instead).
enum Decision {
    /// Cache hit: answer immediately with the cached report.
    Hit(Sender<Result<OptimizeResult>>, OptimizeResult),
    /// Coalesced onto a running leader; the leader will reply.
    Waiting,
    /// Elected leader: run the pipeline and fan the result out.
    Lead(Sender<Result<OptimizeResult>>),
}

/// One admitted optimize job waiting in the intake queue.
struct IntakeJob {
    spec: OptimizeSpec,
    reply: Sender<Result<OptimizeResult>>,
    /// The handle's cancellation token: checked at worker checkout
    /// (queued cancels never start a search) and threaded into the
    /// search so a running job stops mid-wave.
    cancel: CancelToken,
    /// Intake timestamp: the job's deadline origin (queue wait is
    /// charged against `deadline_ms`) and the queue-wait metric source.
    enqueued: Instant,
    /// Canonical key stashed at admission (`None` for unparseable
    /// sources). Valid while the cache generation is unchanged; a worker
    /// re-keys the job if a flush raced it into the queue.
    key: Option<CanonicalKey>,
}

/// The bounded intake queue: admission control happens under this lock
/// ([`Coordinator::submit_optimize`]), workers block on the condvar and
/// check out deadline-sorted same-family batches ([`next_batch`]).
struct Intake {
    state: Mutex<IntakeState>,
    ready: Condvar,
}

struct IntakeState {
    jobs: VecDeque<IntakeJob>,
    /// Set by `Drop`: reject new submissions, drain what's queued, let
    /// the workers exit.
    stopped: bool,
}

/// Block until intake work is available and check out the next batch:
/// the FIFO leader plus up to `opt_batch - 1` queued *distinct* jobs of
/// the leader's kernel family (same generation + α-invariant source
/// hash, different full key), sorted soonest-effective-deadline first
/// behind the leader. Running a family back-to-back on one worker means
/// its searches reuse one pooled arena checkout sequentially instead of
/// faulting several arenas out of the pool at once. Identical-key jobs
/// are deliberately left queued: they coalesce onto the leader's flight
/// via single-flight from whichever worker picks them up, which is
/// strictly cheaper than a batch slot. Returns `None` when the service
/// stopped and the queue is drained.
fn next_batch(intake: &Intake, opt_batch: usize, m: &Metrics) -> Option<Vec<IntakeJob>> {
    let mut st = intake.state.lock().unwrap_or_else(PoisonError::into_inner);
    loop {
        if let Some(leader) = st.jobs.pop_front() {
            let mut batch = vec![leader];
            if let Some(lead_key) = batch[0].key.clone() {
                let family = (lead_key.generation, lead_key.source_hash);
                let mut i = 0;
                while i < st.jobs.len() && batch.len() < opt_batch.max(1) {
                    let compatible = st.jobs[i].key.as_ref().is_some_and(|k| {
                        (k.generation, k.source_hash) == family && *k != lead_key
                    });
                    if compatible {
                        // VecDeque::remove preserves the order of the
                        // remaining queue (FIFO fairness for strangers).
                        batch.extend(st.jobs.remove(i));
                    } else {
                        i += 1;
                    }
                }
            }
            m.queue_depth.store(st.jobs.len() as u64, Ordering::Relaxed);
            drop(st);
            // Deadline-aware order: the leader keeps its FIFO slot (it
            // is the oldest job); followers run soonest absolute
            // deadline first, no-deadline jobs last in intake order
            // (the sort is stable).
            batch[1..].sort_by_key(|j| {
                (
                    j.spec.deadline_ms == 0,
                    j.enqueued + Duration::from_millis(j.spec.deadline_ms),
                )
            });
            return Some(batch);
        }
        if st.stopped {
            return None;
        }
        st = intake.ready.wait(st).unwrap_or_else(PoisonError::into_inner);
    }
}

/// Run one fresh pipeline job with the coordinator's hardening and
/// metric folding: panics are caught and surfaced as
/// [`Error::Coordinator`] (the worker and pool stay alive), search
/// counters and verification tallies fold into `m` exactly once per
/// fresh run, and the arena-pool high-water gauge is refreshed. `ctl`
/// carries the job's cancellation token and deadline origin.
fn run_fresh(spec: &OptimizeSpec, ctl: &JobCtl, m: &Metrics) -> Result<OptimizeResult> {
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pipeline::optimize_ctl(spec, ctl)
    }))
    .unwrap_or_else(|payload| {
        Err(Error::Coordinator(format!(
            "optimize job panicked: {}",
            panic_message(payload.as_ref())
        )))
    });
    match &r {
        Ok(res) => {
            // Fold the fresh run's search counters into the service
            // metrics (cache hits and coalesced waiters describe no new
            // search work and are never re-recorded).
            m.record_search(&res.stats);
            m.verify_passed
                .fetch_add(res.programs_verified as u64, Ordering::Relaxed);
            if let Some(ex) = &res.exec {
                m.exec_parallel_loops
                    .fetch_add(ex.parallel_loops, Ordering::Relaxed);
                m.exec_serial_fallback
                    .fetch_add(u64::from(ex.serial_fallback), Ordering::Relaxed);
                m.exec_threads_high_water
                    .fetch_max(ex.threads_used as u64, Ordering::Relaxed);
            }
            m.arena_pool_high_water.fetch_max(
                crate::dsl::intern::arena_pool_stats().high_water,
                Ordering::Relaxed,
            );
        }
        // A verifier rejection is a soundness catch, not a user error —
        // count it separately so operators see it.
        Err(Error::Verify(_)) => {
            m.verify_rejects.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => {}
    }
    r
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Optimization worker threads.
    pub workers: usize,
    /// Maximum artifact-execution requests drained per batch.
    pub max_batch: usize,
    /// Artifact directory for the runtime thread.
    pub artifact_dir: PathBuf,
    /// Capacity of the optimize-result LRU (entries keyed by the
    /// [`CanonicalKey`]: cache generation, α-invariant source hash, and
    /// the non-source spec fields); repeated service traffic — including
    /// α-renamed or reformatted sources of a cached kernel —
    /// short-circuits the pipeline entirely. `0` keeps the floor of one
    /// entry.
    pub opt_cache_cap: usize,
    /// Admission-control bound on the optimize intake queue: submissions
    /// arriving while this many jobs are already queued are shed with
    /// [`Error::Overloaded`] instead of being accepted (counted in
    /// [`Metrics::shed`], never in `submitted`). `0` keeps a floor of
    /// one slot. Jobs a worker has already checked out don't count
    /// against the bound.
    pub queue_cap: usize,
    /// Maximum optimize jobs a worker checks out per intake batch (the
    /// leader plus same-family followers; see [`Coordinator`] docs).
    /// `0` keeps the floor of one — batching off.
    pub opt_batch: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: 2,
            max_batch: 8,
            artifact_dir: crate::runtime::artifact_dir(),
            opt_cache_cap: 128,
            queue_cap: 256,
            opt_batch: 8,
        }
    }
}

/// A request to the service.
#[derive(Clone, Debug)]
pub enum Request {
    /// Run the optimization pipeline on DSL source.
    Optimize(OptimizeSpec),
    /// Execute a named AOT artifact with f32 inputs.
    ExecArtifact {
        name: String,
        inputs: Vec<(Vec<f32>, Vec<usize>)>,
    },
}

/// A response from the service.
#[derive(Clone, Debug)]
pub enum Response {
    Optimized(OptimizeResult),
    Executed { output: Vec<f32> },
}

/// Typed handle to a submitted optimize job
/// ([`Coordinator::submit_optimize`]).
///
/// **Exactly-once resolution.** The job's outcome is delivered to the
/// handle exactly once, through whichever of [`wait`](Self::wait) /
/// [`wait_timeout`](Self::wait_timeout) first returns it; after that the
/// handle is *resolved* and both report an `already resolved` error
/// (`wait_timeout`'s `Ok(None)` timeout leaves the handle unresolved —
/// keep polling). Dropping an unresolved handle is safe: the worker's
/// reply simply has nowhere to go and is discarded; the job itself still
/// runs to completion (or cancellation) and is cached/counted as usual.
pub struct OptimizeHandle {
    id: u64,
    rx: Receiver<Result<OptimizeResult>>,
    cancel: CancelToken,
    resolved: bool,
}

impl OptimizeHandle {
    /// Service-assigned job id (diagnostics; matches [`JobHandle::id`]).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cooperative cancellation: a still-queued job is dropped at
    /// worker checkout (it resolves with an error, counted in
    /// [`Metrics::cancelled_before_start`]); a *running* search observes
    /// the token at its next checkpoint — between expansion waves, or
    /// mid-wave at a shard's next depth boundary — and returns its
    /// best-so-far report with `stats.cancelled` set (counted in
    /// [`Metrics::search_cancelled`], never cached). Idempotent, and a
    /// no-op after the job resolved. One deliberate asymmetry: a job
    /// that *coalesced* onto another request's identical in-flight
    /// search shares that search, so cancelling it abandons this
    /// handle's interest but does not stop the shared flight.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Block until the job resolves.
    pub fn wait(self) -> Result<OptimizeResult> {
        if self.resolved {
            return Err(Error::Coordinator(
                "job already resolved; an OptimizeHandle resolves exactly once".into(),
            ));
        }
        self.rx
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped without responding".into()))?
    }

    /// Wait up to `timeout` for the job to resolve. `Ok(None)` means it
    /// is still pending (the handle stays live — poll again or
    /// [`cancel`](Self::cancel)); `Ok(Some(_))`/`Err(_)` resolve the
    /// handle, and every later call reports `already resolved`.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<Option<OptimizeResult>> {
        if self.resolved {
            return Err(Error::Coordinator(
                "job already resolved; an OptimizeHandle resolves exactly once".into(),
            ));
        }
        match self.rx.recv_timeout(timeout) {
            Ok(r) => {
                self.resolved = true;
                r.map(Some)
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                self.resolved = true;
                Err(Error::Coordinator("worker dropped without responding".into()))
            }
        }
    }
}

/// Handle to a submitted job ([`Coordinator::submit`]); resolves exactly
/// once. The enum-shaped counterpart of [`OptimizeHandle`] — optimize
/// jobs wrap one and inherit its lifecycle (including
/// [`cancel`](Self::cancel)).
pub struct JobHandle {
    pub id: u64,
    inner: JobHandleInner,
}

enum JobHandleInner {
    Opt(OptimizeHandle),
    Exec(Receiver<Result<Response>>),
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<Response> {
        match self.inner {
            JobHandleInner::Opt(h) => h.wait().map(Response::Optimized),
            JobHandleInner::Exec(rx) => rx
                .recv()
                .map_err(|_| Error::Coordinator("worker dropped without responding".into()))?,
        }
    }

    /// Request cooperative cancellation ([`OptimizeHandle::cancel`]).
    /// Artifact-execution jobs have no cancellation point; for them this
    /// is a no-op.
    pub fn cancel(&self) {
        if let JobHandleInner::Opt(h) = &self.inner {
            h.cancel();
        }
    }
}

enum RtWork {
    Exec {
        name: String,
        inputs: Vec<(Vec<f32>, Vec<usize>)>,
        reply: Sender<Result<Response>>,
    },
    Stop,
}

/// The running service.
pub struct Coordinator {
    next_id: std::sync::atomic::AtomicU64,
    intake: Arc<Intake>,
    rt_tx: SyncSender<RtWork>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    rt_thread: Option<JoinHandle<()>>,
    /// Admission bound on the intake queue ([`Config::queue_cap`]).
    queue_cap: usize,
    /// Generation stamp mixed into every optimize-cache key. Seeded from
    /// [`crate::costmodel::COST_MODEL_VERSION`] (so a cost-model bump
    /// invalidates results cached under the old model) and advanced by
    /// [`Coordinator::flush_opt_cache`]; old-generation entries simply
    /// stop matching and age out of the LRU.
    opt_generation: Arc<std::sync::atomic::AtomicU64>,
}

/// Process one checked-out optimize job end to end: queue-wait
/// accounting, the pre-start cancellation gate, the hit / coalesce /
/// lead decision under the [`OptShared`] lock, and — as leader — the
/// fresh pipeline run, publish, and fan-out to coalesced waiters.
fn process_opt_job(
    job: IntakeJob,
    m: &Metrics,
    shared: &Mutex<OptShared>,
    generation: &std::sync::atomic::AtomicU64,
) {
    let IntakeJob {
        spec,
        reply,
        cancel,
        enqueued,
        key,
    } = job;
    m.record_queue_wait(enqueued.elapsed());
    // Cancelled while still queued: resolve without starting (or
    // joining) a search. Counted as failed — the caller asked for a
    // report and is not getting one.
    if cancel.is_cancelled() {
        m.cancelled_before_start.fetch_add(1, Ordering::Relaxed);
        m.failed.fetch_add(1, Ordering::Relaxed);
        let _ = reply.send(Err(Error::Coordinator(
            "job cancelled before the search started".into(),
        )));
        return;
    }
    // Deadline propagation: the search deadline is measured from intake,
    // so the wait recorded above is charged against the job's budget.
    let ctl = JobCtl {
        cancel: Some(cancel),
        deadline_origin: Some(enqueued),
    };
    let stamp = generation.load(Ordering::Relaxed);
    // The key stashed at admission is valid unless a flush raced the job
    // into the queue; re-key under the current generation then.
    let key = match key {
        Some(k) if k.generation == stamp => Some(k),
        _ => spec.canonical_key(stamp),
    };
    // An unparseable source has no canonical key: run it directly
    // (uncached, uncoalesced) for its parse error.
    let Some(key) = key else {
        let r = run_fresh(&spec, &ctl, m);
        if r.is_ok() {
            m.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            m.failed.fetch_add(1, Ordering::Relaxed);
        }
        let _ = reply.send(r);
        return;
    };
    let decision = {
        let mut st = shared.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(entry) = st.cache.get(&key) {
            if entry.source == spec.source {
                m.opt_cache_hits_exact.fetch_add(1, Ordering::Relaxed);
            } else {
                m.opt_cache_hits_canonical.fetch_add(1, Ordering::Relaxed);
            }
            Decision::Hit(reply, entry.result)
        } else if let Some(waiters) = st.inflight.get_mut(&key) {
            waiters.push(reply);
            m.opt_coalesced.fetch_add(1, Ordering::Relaxed);
            Decision::Waiting
        } else {
            st.inflight.insert(key.clone(), Vec::new());
            Decision::Lead(reply)
        }
    };
    match decision {
        Decision::Hit(reply, res) => {
            m.completed.fetch_add(1, Ordering::Relaxed);
            let _ = reply.send(Ok(res));
        }
        Decision::Waiting => {}
        Decision::Lead(reply) => {
            // A panicking pipeline run fails this job *and every
            // coalesced waiter* (all reply senders are drained below)
            // and leaves the worker pool alive.
            let r = run_fresh(&spec, &ctl, m);
            // Publish and drain under the same lock that admits
            // waiters, so no job can register against a flight that has
            // already resolved.
            let waiters = {
                let mut st = shared.lock().unwrap_or_else(PoisonError::into_inner);
                if let Ok(res) = &r {
                    // A cancelled run is truncated at *this* caller's
                    // request: deliver it (to the leader and to anyone
                    // who coalesced onto the shared flight) but never
                    // cache it — the next request for this key deserves
                    // the full search.
                    if !res.stats.cancelled {
                        st.cache.put(
                            key.clone(),
                            CacheEntry {
                                source: spec.source.clone(),
                                result: res.clone(),
                            },
                        );
                    }
                }
                st.inflight.remove(&key).unwrap_or_default()
            };
            let resolved = 1 + waiters.len() as u64;
            if r.is_ok() {
                m.completed.fetch_add(resolved, Ordering::Relaxed);
            } else {
                m.failed.fetch_add(resolved, Ordering::Relaxed);
            }
            match r {
                Ok(res) => {
                    for wtr in waiters {
                        let _ = wtr.send(Ok(res.clone()));
                    }
                    let _ = reply.send(Ok(res));
                }
                Err(e) => {
                    for wtr in waiters {
                        let _ = wtr.send(Err(e.clone()));
                    }
                    let _ = reply.send(Err(e));
                }
            }
        }
    }
}

impl Coordinator {
    /// Start the service threads.
    pub fn start(cfg: Config) -> Result<Self> {
        let metrics = Arc::new(Metrics::default());
        let intake = Arc::new(Intake {
            state: Mutex::new(IntakeState {
                jobs: VecDeque::new(),
                stopped: false,
            }),
            ready: Condvar::new(),
        });
        // Result LRU + single-flight table shared by all workers, keyed
        // canonically ([`OptimizeSpec::canonical_key`]): repeated
        // optimize traffic — including α-renamed or reformatted sources
        // of a cached kernel — short-circuits the pipeline, and
        // identical concurrent requests collapse onto one running
        // search. Keys carry the cache generation so a flush (or a
        // cost-model version bump) invalidates without touching entries.
        let opt_shared = Arc::new(Mutex::new(OptShared {
            cache: crate::util::Lru::new(cfg.opt_cache_cap),
            inflight: HashMap::new(),
        }));
        let opt_generation = Arc::new(std::sync::atomic::AtomicU64::new(
            crate::costmodel::COST_MODEL_VERSION,
        ));
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers.max(1) {
            let intake = intake.clone();
            let m = metrics.clone();
            let shared = opt_shared.clone();
            let generation = opt_generation.clone();
            let opt_batch = cfg.opt_batch;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hofdla-opt-{w}"))
                    .spawn(move || {
                        // Locks recover from poisoning throughout: a
                        // panic in any worker must not cascade into
                        // every other worker dying on `unwrap()` — which
                        // would strand queued jobs forever (their reply
                        // senders sit in the queue, so callers block,
                        // not error).
                        while let Some(batch) = next_batch(&intake, opt_batch, &m) {
                            m.record_batch(batch.len() as u64);
                            // Same-family jobs run back-to-back on this
                            // worker: each search returns its pooled
                            // arena on completion and the next checks
                            // the same one straight back out.
                            for job in batch {
                                process_opt_job(job, &m, &shared, &generation);
                            }
                        }
                    })
                    .map_err(|e| Error::Coordinator(format!("spawn: {e}")))?,
            );
        }

        // Runtime thread: owns the PJRT client; batches artifact requests.
        let (rt_tx, rt_rx) = sync_channel::<RtWork>(1024);
        let m = metrics.clone();
        let max_batch = cfg.max_batch.max(1);
        let art_dir = cfg.artifact_dir.clone();
        let rt_thread = std::thread::Builder::new()
            .name("hofdla-runtime".into())
            .spawn(move || {
                let mut rt = match crate::runtime::Runtime::cpu() {
                    Ok(rt) => rt,
                    Err(e) => {
                        while let Ok(w) = rt_rx.recv() {
                            match w {
                                RtWork::Exec { reply, .. } => {
                                    let _ = reply.send(Err(Error::Runtime(format!(
                                        "PJRT unavailable: {e}"
                                    ))));
                                }
                                RtWork::Stop => break,
                            }
                        }
                        return;
                    }
                };
                'outer: loop {
                    let first = match rt_rx.recv() {
                        Ok(w) => w,
                        Err(_) => break,
                    };
                    let mut batch = Vec::with_capacity(max_batch);
                    match first {
                        RtWork::Stop => break,
                        w => batch.push(w),
                    }
                    let mut stop_after = false;
                    while batch.len() < max_batch {
                        match rt_rx.try_recv() {
                            Ok(RtWork::Stop) => {
                                stop_after = true;
                                break;
                            }
                            Ok(w) => batch.push(w),
                            Err(_) => break,
                        }
                    }
                    m.exec_batches.fetch_add(1, Ordering::Relaxed);
                    m.max_batch_seen
                        .fetch_max(batch.len() as u64, Ordering::Relaxed);
                    Self::run_batch(&mut rt, &art_dir, batch, &m);
                    if stop_after {
                        break 'outer;
                    }
                }
            })
            .map_err(|e| Error::Coordinator(format!("spawn runtime: {e}")))?;

        Ok(Coordinator {
            next_id: std::sync::atomic::AtomicU64::new(1),
            intake,
            rt_tx,
            metrics,
            queue_cap: cfg.queue_cap.max(1),
            workers,
            rt_thread: Some(rt_thread),
            opt_generation,
        })
    }

    /// Invalidate every cached optimize result by advancing the cache
    /// generation (ROADMAP: cache invalidation policy for the coordinator
    /// LRU). Call after anything that changes ranking semantics — e.g. a
    /// cost model that learns online.
    ///
    /// Canonical entries are invalidated with everything else: the
    /// generation lives *inside* the [`CanonicalKey`], so post-flush
    /// requests key differently and can never match a pre-flush entry.
    /// In-flight single-flight groups are **orphaned**, not aborted: a
    /// running leader finishes, answers every waiter that coalesced with
    /// it (they asked the pre-flush question and get its answer — one
    /// coherent result, never a half-flushed mix), and publishes under
    /// its old-generation key, which no future request matches and which
    /// ages out of the LRU on its own. Jobs keyed *after* the flush see
    /// the new generation, find no matching flight, and start a fresh
    /// search.
    pub fn flush_opt_cache(&self) {
        self.opt_generation.fetch_add(1, Ordering::Relaxed);
        self.metrics.opt_cache_flushes.fetch_add(1, Ordering::Relaxed);
    }

    /// The current optimize-cache generation (diagnostics / tests).
    pub fn opt_cache_generation(&self) -> u64 {
        self.opt_generation.load(Ordering::Relaxed)
    }

    fn run_batch(
        rt: &mut crate::runtime::Runtime,
        art_dir: &std::path::Path,
        batch: Vec<RtWork>,
        m: &Metrics,
    ) {
        for w in batch {
            let RtWork::Exec {
                name,
                inputs,
                reply,
            } = w
            else {
                continue;
            };
            let path = art_dir.join(format!("{name}.hlo.txt"));
            let before = rt.cache_len();
            let r = rt.load(&path).and_then(|exe| {
                if rt.cache_len() == before {
                    m.exec_cache_hits.fetch_add(1, Ordering::Relaxed);
                }
                let refs: Vec<(&[f32], &[usize])> = inputs
                    .iter()
                    .map(|(d, s)| (d.as_slice(), s.as_slice()))
                    .collect();
                rt.run_f32(&exe, &refs)
            });
            match r {
                Ok(output) => {
                    m.completed.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(Ok(Response::Executed { output }));
                }
                Err(e) => {
                    m.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(Err(e));
                }
            }
        }
    }

    /// Submit an optimize job through the typed front door: validate the
    /// spec, apply admission control, and return an [`OptimizeHandle`]
    /// that resolves to the [`OptimizeResult`] directly.
    ///
    /// Errors at submission (nothing was queued, nothing counts as
    /// `submitted`):
    /// - a knob out of bounds ([`OptimizeSpec::validate`]),
    /// - [`Error::Overloaded`] when the intake queue is at
    ///   [`Config::queue_cap`] — counted in [`Metrics::shed`]; back off
    ///   and retry,
    /// - `service stopped` when the coordinator is shutting down.
    pub fn submit_optimize(&self, spec: OptimizeSpec) -> Result<OptimizeHandle> {
        // Fail fast on invalid knobs: a spec that cannot run must not
        // occupy a queue slot other jobs could be admitted to.
        spec.validate()?;
        let cancel = CancelToken::new();
        let (tx, rx) = std::sync::mpsc::channel();
        // Key outside the intake lock (keying parses the source); the
        // worker re-keys iff a flush races the job into the queue.
        let stamp = self.opt_generation.load(Ordering::Relaxed);
        let job = IntakeJob {
            key: spec.canonical_key(stamp),
            spec,
            reply: tx,
            cancel: cancel.clone(),
            enqueued: Instant::now(),
        };
        {
            let mut st = self
                .intake
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            if st.stopped {
                return Err(Error::Coordinator("service stopped".into()));
            }
            // Admission control: shed at capacity, under the same lock
            // that admits — the depth a rejection reports is the depth
            // that caused it.
            let depth = st.jobs.len();
            if depth >= self.queue_cap {
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Overloaded { queue_depth: depth });
            }
            st.jobs.push_back(job);
            let depth = st.jobs.len() as u64;
            self.metrics.queue_depth.store(depth, Ordering::Relaxed);
            self.metrics
                .queue_high_water
                .fetch_max(depth, Ordering::Relaxed);
        }
        self.intake.ready.notify_one();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok(OptimizeHandle {
            id,
            rx,
            cancel,
            resolved: false,
        })
    }

    /// Submit a job; returns a handle that resolves exactly once.
    /// Optimize requests delegate to [`Coordinator::submit_optimize`]
    /// (same validation, admission control, and cancellation support).
    pub fn submit(&self, req: Request) -> Result<JobHandle> {
        match req {
            Request::Optimize(spec) => {
                let h = self.submit_optimize(spec)?;
                Ok(JobHandle {
                    id: h.id,
                    inner: JobHandleInner::Opt(h),
                })
            }
            Request::ExecArtifact { name, inputs } => {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                let (tx, rx) = std::sync::mpsc::channel();
                self.rt_tx
                    .send(RtWork::Exec {
                        name,
                        inputs,
                        reply: tx,
                    })
                    .map_err(|_| Error::Coordinator("service stopped".into()))?;
                Ok(JobHandle {
                    id,
                    inner: JobHandleInner::Exec(rx),
                })
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: Request) -> Result<Response> {
        self.submit(req)?.wait()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        {
            let mut st = self
                .intake
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            st.stopped = true;
        }
        // Workers drain whatever was admitted before the stop flag, then
        // exit — no accepted job is stranded.
        self.intake.ready.notify_all();
        let _ = self.rt_tx.send(RtWork::Stop);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(t) = self.rt_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opt_spec(n: usize) -> OptimizeSpec {
        OptimizeSpec::builder(
            "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
        )
        .input("A", &[n, n])
        .input("B", &[n, n])
        .top_k(6)
        .verify(true)
        .build()
        .unwrap()
    }

    /// Shapes whose stride/extent products overflow `usize`: panics in
    /// debug builds (the profile `cargo test` runs); in release the
    /// wrapped layout fails shape checking instead.
    fn poison_spec() -> OptimizeSpec {
        OptimizeSpec::builder(
            "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
        )
        .input("A", &[usize::MAX, usize::MAX])
        .input("B", &[usize::MAX, usize::MAX])
        .top_k(4)
        .build()
        .unwrap()
    }

    #[test]
    fn optimize_roundtrip() {
        let c = Coordinator::start(Config {
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        let Response::Optimized(r) = c.call(Request::Optimize(opt_spec(16))).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(r.variants_explored, 6);
        assert_eq!(r.ranking.first().unwrap().0, r.best);
        assert_eq!(r.best, "map1 rnz map2"); // Table 1 winner
        // The spec's verify knob is on: the winner was certified, and the
        // service counter saw it.
        assert_eq!(r.programs_verified, 1);
        assert_eq!(c.metrics.verify_passed.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.verify_rejects.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn jobs_route_to_matching_requests() {
        // Distinct problem sizes in flight concurrently; every response
        // must carry its own request's size.
        let c = Coordinator::start(Config {
            workers: 4,
            ..Default::default()
        })
        .unwrap();
        let sizes = [4usize, 8, 16, 32, 4, 8, 16, 32, 64, 64];
        let handles: Vec<(usize, JobHandle)> = sizes
            .iter()
            .map(|&n| (n, c.submit(Request::Optimize(opt_spec(n))).unwrap()))
            .collect();
        for (n, h) in handles {
            let Response::Optimized(r) = h.wait().unwrap() else { panic!() };
            assert_eq!(r.input_elems, 2 * n * n, "routing mixed up sizes");
        }
        let m = &c.metrics;
        assert_eq!(m.submitted.load(Ordering::Relaxed), 10);
        assert_eq!(m.completed.load(Ordering::Relaxed), 10);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn optimize_results_are_cached() {
        let c = Coordinator::start(Config {
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        let mut after_first = 0;
        for i in 0..3 {
            let Response::Optimized(r) = c.call(Request::Optimize(opt_spec(16))).unwrap() else {
                panic!("wrong response type")
            };
            assert_eq!(r.variants_explored, 6);
            assert_eq!(r.best, "map1 rnz map2");
            if i == 0 {
                after_first = c.metrics.search_generated.load(Ordering::Relaxed);
                assert!(after_first > 0, "fresh run must record search work");
            }
        }
        // Serial identical calls: first misses, the rest hit the LRU —
        // byte-identical source, so the hits classify as exact.
        assert_eq!(c.metrics.opt_cache_hits_exact.load(Ordering::Relaxed), 2);
        assert_eq!(c.metrics.opt_cache_hits_canonical.load(Ordering::Relaxed), 0);
        assert_eq!(c.metrics.completed.load(Ordering::Relaxed), 3);
        // Cache hits describe no new search work: counters are unchanged.
        assert_eq!(
            c.metrics.search_generated.load(Ordering::Relaxed),
            after_first
        );
        // A different spec misses — and records fresh search work.
        let Response::Optimized(_) = c.call(Request::Optimize(opt_spec(8))).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(c.metrics.opt_cache_hits(), 2);
        assert!(c.metrics.search_generated.load(Ordering::Relaxed) > after_first);
    }

    #[test]
    fn flush_invalidates_optimize_cache() {
        let c = Coordinator::start(Config {
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        let g0 = c.opt_cache_generation();
        assert_eq!(g0, crate::costmodel::COST_MODEL_VERSION);
        // Warm the cache, hit it once.
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        assert_eq!(c.metrics.opt_cache_hits(), 1);
        // Flush: the same spec must re-run the pipeline (no new hit), and
        // the refreshed entry must serve hits again afterwards.
        c.flush_opt_cache();
        assert_eq!(c.opt_cache_generation(), g0 + 1);
        assert_eq!(c.metrics.opt_cache_flushes.load(Ordering::Relaxed), 1);
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        assert_eq!(c.metrics.opt_cache_hits(), 1);
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        assert_eq!(c.metrics.opt_cache_hits(), 2);
    }

    #[test]
    fn alpha_renamed_resubmission_is_canonical_cache_hit() {
        // ISSUE 8 acceptance criterion: an α-renamed resubmission of a
        // completed job is a cache hit — the `canonical` counter
        // increments and the search counters do not move.
        let c = Coordinator::start(Config {
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        let Response::Optimized(first) = c.call(Request::Optimize(opt_spec(16))).unwrap() else {
            panic!("wrong response type")
        };
        let expanded = c.metrics.search_expanded.load(Ordering::Relaxed);
        let generated = c.metrics.search_generated.load(Ordering::Relaxed);
        assert!(generated > 0);
        // Same kernel, different binder names, formatting and comments.
        let mut renamed = opt_spec(16);
        renamed.source = "; alpha-renamed resubmission of the matmul kernel\n\
                          (map (lam (rowOfA)\n\
                            (map (lam (colOfB) (rnz + * rowOfA colOfB))\n\
                              (flip 0 (in B))))\n\
                            (in A))"
            .into();
        assert_ne!(renamed.source, opt_spec(16).source);
        let Response::Optimized(second) = c.call(Request::Optimize(renamed)).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(c.metrics.opt_cache_hits_canonical.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.opt_cache_hits_exact.load(Ordering::Relaxed), 0);
        // Zero search delta: the renamed job performed no new search work.
        assert_eq!(c.metrics.search_expanded.load(Ordering::Relaxed), expanded);
        assert_eq!(c.metrics.search_generated.load(Ordering::Relaxed), generated);
        // The cached report is returned bit-identically.
        assert_eq!(format!("{:?}", first.ranking), format!("{:?}", second.ranking));
        assert_eq!(first.best, second.best);
        assert_eq!(first.best_expr, second.best_expr);
        // A byte-identical resubmission classifies as exact, not canonical.
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        assert_eq!(c.metrics.opt_cache_hits_exact.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.opt_cache_hits_canonical.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_identical_specs_coalesce_onto_one_search() {
        // N identical concurrent submissions: one leader runs the search,
        // the other N-1 coalesce onto it and receive the same result.
        // The subdivided n=64 search is slow enough (hundreds of ms in
        // the debug profile tests run under) that all followers are
        // picked up while the leader is still searching.
        let c = Coordinator::start(Config {
            workers: 4,
            ..Default::default()
        })
        .unwrap();
        let mut spec = opt_spec(64);
        spec.subdivide_rnz = Some(4);
        spec.top_k = 12;
        let n = 8u64;
        let handles: Vec<JobHandle> = (0..n)
            .map(|_| c.submit(Request::Optimize(spec.clone())).unwrap())
            .collect();
        let mut rankings = Vec::new();
        for h in handles {
            let Response::Optimized(r) = h.wait().unwrap() else { panic!() };
            rankings.push(format!("{:?} best={} {}", r.ranking, r.best, r.best_expr));
        }
        assert!(
            rankings.windows(2).all(|w| w[0] == w[1]),
            "coalesced waiters saw divergent results"
        );
        let m = &c.metrics;
        assert_eq!(m.opt_coalesced.load(Ordering::Relaxed), n - 1);
        assert_eq!(m.opt_cache_hits(), 0);
        assert_eq!(m.completed.load(Ordering::Relaxed), n);
        assert_eq!(m.in_flight(), 0);
        // `search_expanded` folded exactly once for the whole burst: a
        // post-flush fresh run of the same spec adds the same amount
        // again (the search is deterministic).
        let expanded_once = m.search_expanded.load(Ordering::Relaxed);
        assert!(expanded_once > 0);
        c.flush_opt_cache();
        c.call(Request::Optimize(spec)).unwrap();
        assert_eq!(m.search_expanded.load(Ordering::Relaxed), 2 * expanded_once);
    }

    #[test]
    fn flush_racing_inflight_search_stays_coherent() {
        // Regression test (ISSUE 8): a flush while a single-flight group
        // is mid-search must orphan the flight coherently — every waiter
        // still gets the leader's (pre-flush) result, the orphaned entry
        // is invisible to post-flush requests, and the new generation
        // caches normally afterwards.
        let c = Coordinator::start(Config {
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        let mut spec = opt_spec(64);
        spec.subdivide_rnz = Some(4);
        spec.top_k = 12;
        let handles: Vec<JobHandle> = (0..3)
            .map(|_| c.submit(Request::Optimize(spec.clone())).unwrap())
            .collect();
        // Let the leader start and the waiters coalesce, then flush
        // mid-flight (the debug-profile search runs much longer than
        // this; if it somehow finished already the assertions below
        // still hold — the race is just not exercised).
        std::thread::sleep(std::time::Duration::from_millis(50));
        c.flush_opt_cache();
        let mut rankings = Vec::new();
        for h in handles {
            let Response::Optimized(r) = h.wait().unwrap() else { panic!() };
            rankings.push(format!("{:?}", r.ranking));
        }
        assert!(
            rankings.windows(2).all(|w| w[0] == w[1]),
            "waiters of the orphaned flight saw divergent results"
        );
        assert_eq!(c.metrics.completed.load(Ordering::Relaxed), 3);
        assert_eq!(c.metrics.in_flight(), 0);
        // The orphaned flight published under the *old* generation: a
        // post-flush resubmission re-searches…
        let generated = c.metrics.search_generated.load(Ordering::Relaxed);
        let hits = c.metrics.opt_cache_hits();
        c.call(Request::Optimize(spec.clone())).unwrap();
        assert!(
            c.metrics.search_generated.load(Ordering::Relaxed) > generated,
            "post-flush resubmission must run a fresh search"
        );
        assert_eq!(c.metrics.opt_cache_hits(), hits);
        // …and the refreshed entry serves hits under the new generation.
        c.call(Request::Optimize(spec)).unwrap();
        assert_eq!(c.metrics.opt_cache_hits(), hits + 1);
    }

    #[test]
    fn panicking_flight_errors_every_job_and_leaves_pool_alive() {
        // A burst of identical panicking jobs across several workers:
        // whichever jobs coalesce onto a panicking leader must receive
        // its error (every handle resolving at all — rather than hanging
        // — is exactly that delivery), nothing may be cached, the
        // in-flight table must drain, and the pool must keep serving.
        let c = Coordinator::start(Config {
            workers: 4,
            ..Default::default()
        })
        .unwrap();
        let poison = poison_spec();
        let n = 8u64;
        let handles: Vec<JobHandle> = (0..n)
            .map(|_| c.submit(Request::Optimize(poison.clone())).unwrap())
            .collect();
        for h in handles {
            // Shapes whose stride/extent products overflow `usize` panic
            // in debug builds (the profile `cargo test` runs); in release
            // the wrapped layout fails shape checking instead. Either way
            // every job must resolve promptly.
            let r = h.wait();
            if cfg!(debug_assertions) {
                assert!(r.is_err(), "panicking flight must surface as an error");
            }
        }
        if cfg!(debug_assertions) {
            assert_eq!(c.metrics.failed.load(Ordering::Relaxed), n);
            assert_eq!(c.metrics.opt_cache_hits(), 0, "errors must never be cached");
        }
        assert_eq!(c.metrics.in_flight(), 0);
        // The pool survived and the single-flight table drained: fresh
        // work (including the formerly-poisoned key's generation) serves.
        let Response::Optimized(r) = c.call(Request::Optimize(opt_spec(8))).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(r.best, "map1 rnz map2");
    }

    #[test]
    fn worker_pool_survives_panicking_jobs() {
        // A panicking `pipeline::optimize` used to unwind the worker with
        // the job's reply channel still queued behind poisoned locks:
        // every other worker then died on `lock().unwrap()` and later
        // callers blocked forever. The pool must instead fail the job and
        // keep serving.
        let c = Coordinator::start(Config {
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        // The poison job must resolve — promptly and with an error —
        // instead of hanging.
        let poison = poison_spec();
        for _ in 0..3 {
            let r = c.call(Request::Optimize(poison.clone()));
            if cfg!(debug_assertions) {
                assert!(r.is_err(), "panicking job must surface as an error");
            }
        }
        if cfg!(debug_assertions) {
            assert_eq!(c.metrics.failed.load(Ordering::Relaxed), 3);
        }
        // The single worker survived all three panics and still serves.
        let Response::Optimized(r) = c.call(Request::Optimize(opt_spec(8))).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(r.best, "map1 rnz map2");
        assert_eq!(c.metrics.in_flight(), 0);
    }

    #[test]
    fn parse_errors_fail_cleanly() {
        let c = Coordinator::start(Config::default()).unwrap();
        let bad = OptimizeSpec::builder("(map (lam").top_k(3).build().unwrap();
        assert!(c.call(Request::Optimize(bad)).is_err());
        assert_eq!(c.metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn artifact_execution_and_batching() {
        if !crate::runtime::artifact_path("matmul_xla_256").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        if !crate::runtime::pjrt_available() {
            eprintln!("skipping: PJRT runtime unavailable");
            return;
        }
        let c = Coordinator::start(Config {
            workers: 1,
            max_batch: 4,
            ..Default::default()
        })
        .unwrap();
        let n = 256usize;
        let a = vec![1f32; n * n];
        let b = vec![2f32; n * n];
        let mk = || Request::ExecArtifact {
            name: "matmul_xla_256".into(),
            inputs: vec![(a.clone(), vec![n, n]), (b.clone(), vec![n, n])],
        };
        let handles: Vec<JobHandle> = (0..6).map(|_| c.submit(mk()).unwrap()).collect();
        for h in handles {
            let Response::Executed { output } = h.wait().unwrap() else { panic!() };
            assert_eq!(output.len(), n * n);
            assert!((output[0] - (2 * n) as f32).abs() < 1e-2);
        }
        let m = &c.metrics;
        assert!(m.max_batch_seen.load(Ordering::Relaxed) <= 4);
        assert!(m.exec_cache_hits.load(Ordering::Relaxed) >= 5);
        let missing = Request::ExecArtifact {
            name: "no_such_artifact".into(),
            inputs: vec![],
        };
        assert!(c.call(missing).is_err());
    }
}
