//! The optimization pipeline executed by worker threads:
//! parse → typecheck → fuse → (optional subdivision) → enumerate →
//! rank (cost model or cache simulator) → report.
//!
//! This is the paper's §3-4 flow packaged as a service call.

use crate::cachesim::{simulate, HierarchyConfig};
use crate::costmodel::estimate;
use crate::dsl;
use crate::enumerate::{
    enumerate_search, CancelToken, SearchOptions, SearchResult, SearchStats, Variant,
    DEFAULT_PRUNE_SLACK, MAX_SEARCH_SHARDS,
};
use crate::exec::{execute, execute_threaded, lower, ExecReport, MAX_EXEC_THREADS};
use crate::layout::Layout;
use crate::rewrite::{fusion, normalize, subdivision, Ctx};
use crate::typecheck::Env;
use crate::{Error, Result};

/// How variants are ranked.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RankBy {
    /// Analytical cost model (fast; the "early cut" metric).
    CostModel,
    /// Trace-driven cache simulation on the CPU hierarchy (slower,
    /// sharper).
    CacheSim,
}

/// An optimization request. `Eq + Hash` so the coordinator can key its
/// result cache directly by the spec.
///
/// `#[non_exhaustive]`: construct through [`OptimizeSpec::builder`]
/// (which validates budget/deadline/shard bounds at build time) and
/// adjust fields afterwards if needed — future knobs (queue class,
/// priority) must not be breaking changes for downstream crates.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
#[non_exhaustive]
pub struct OptimizeSpec {
    /// DSL source (s-expression; see [`crate::dsl::parse`]).
    pub source: String,
    /// Input name → row-major shape (outermost first).
    pub inputs: Vec<(String, Vec<usize>)>,
    pub rank_by: RankBy,
    /// Subdivide every reduction with this block size before enumerating
    /// (the paper's Table 2 move).
    pub subdivide_rnz: Option<usize>,
    /// Keep this many rows in the report.
    pub top_k: usize,
    /// Cut dominated candidates inside the enumeration BFS: branch-and-
    /// bound comparing each candidate's partial-spine lower bound
    /// ([`crate::costmodel::spine_lower_bound_id`]) against the shared
    /// best-known score, with [`DEFAULT_PRUNE_SLACK`]. Cut candidates are
    /// never lowered, scored, or extracted, and they leave the report —
    /// `variants_explored` and the ranking shrink to the survivors — but
    /// the winner can never be cut (the bound never exceeds the true
    /// score, and cut candidates still expand, so the best rearrangement
    /// is always reached, scored, and ranked first, identical to
    /// exhaustive mode). `false` keeps the search exhaustive. Applies to
    /// [`RankBy::CostModel`] jobs only — the bound is a cost-model bound,
    /// and CacheSim jobs re-rank the kept variants with the simulator, so
    /// maintaining it there would be pure overhead.
    pub prune: bool,
    /// Statically verify the winning candidate's lowered program
    /// ([`crate::verify::verify`]) before reporting it: bounds,
    /// initialization and map-write-disjointness, certified per job. A
    /// rejection fails the job with [`Error::Verify`] (counted in
    /// [`super::Metrics::verify_rejects`]) rather than handing an unsound
    /// program to callers. Debug/test builds verify every lowered
    /// candidate regardless; this knob is the production gate.
    pub verify: bool,
    /// Anytime node budget forwarded to
    /// [`SearchOptions::budget`](crate::enumerate::SearchOptions::budget):
    /// stop after this many frontier expansions and report the
    /// best-so-far winner with a certified gap. `0` = unlimited (the
    /// exhaustive default).
    pub budget: u64,
    /// Per-job wall-clock deadline in milliseconds, measured from
    /// pipeline entry and forwarded to
    /// [`SearchOptions::deadline`](crate::enumerate::SearchOptions::deadline)
    /// (a deadline *cancels* in-flight shard work cooperatively). `0` =
    /// unlimited. Values above [`MAX_DEADLINE_MS`] are rejected by
    /// [`OptimizeSpec::validate`] — a day-plus "deadline" is a typo'd
    /// unit, not a latency contract.
    pub deadline_ms: u64,
    /// Explicit shard fan-out for this job's search, forwarded to
    /// [`SearchOptions::shards`](crate::enumerate::SearchOptions::shards).
    /// `0` = auto (one shard per available core). Values above
    /// [`crate::enumerate::MAX_SEARCH_SHARDS`] are rejected by
    /// [`OptimizeSpec::validate`] rather than silently clamped. The
    /// search result is bit-identical at every shard width (the
    /// deterministic-merge contract, pinned by the CI `SEARCH_SHARDS`
    /// matrix) — this knob trades latency against machine load only.
    pub shards: usize,
    /// Execution rehearsal: lower the winning candidate and *run* it on
    /// deterministic synthetic inputs before reporting. `0` = off (the
    /// default — report without executing); `1` = serial rehearsal; `>= 2`
    /// = additionally run the certificate-gated threaded executor
    /// ([`crate::exec::execute_threaded`]) with this many workers and
    /// assert the output is bit-identical to serial. Values above
    /// [`crate::exec::MAX_EXEC_THREADS`] are rejected by
    /// [`OptimizeSpec::validate`] rather than silently clamped. The
    /// resulting [`ExecRehearsal`] report is folded into
    /// [`super::Metrics`] (`exec_parallel_loops` / `exec_serial_fallback`
    /// / `exec_threads_high_water`).
    pub exec_threads: usize,
}

/// Upper bound accepted for [`OptimizeSpec::deadline_ms`] (24 hours).
/// Anything longer is indistinguishable from "no deadline" for a service
/// call and almost certainly a unit mistake; spell "no deadline" as `0`.
pub const MAX_DEADLINE_MS: u64 = 24 * 60 * 60 * 1000;

impl OptimizeSpec {
    /// Start building a spec for `source` with validated knobs:
    /// [`OptimizeSpecBuilder::build`] checks budget/deadline/shard
    /// bounds and returns `Result`, so an invalid spec is caught at
    /// construction — before it is submitted, queued, or keyed — instead
    /// of deep inside a worker. Defaults match the CLI: cost-model
    /// ranking, `top_k` 12, no subdivision, no pruning, no verification,
    /// unlimited budget/deadline, auto shards.
    pub fn builder(source: impl Into<String>) -> OptimizeSpecBuilder {
        OptimizeSpecBuilder {
            spec: OptimizeSpec {
                source: source.into(),
                inputs: Vec::new(),
                rank_by: RankBy::CostModel,
                subdivide_rnz: None,
                top_k: 12,
                prune: false,
                verify: false,
                budget: 0,
                deadline_ms: 0,
                shards: 0,
                exec_threads: 0,
            },
        }
    }

    /// Validate the knob bounds: `0` means unlimited/auto for
    /// [`budget`](Self::budget), [`deadline_ms`](Self::deadline_ms) and
    /// [`shards`](Self::shards); a nonsense deadline (above
    /// [`MAX_DEADLINE_MS`]), a budget that cannot fit the platform's
    /// `usize`, a shard request above
    /// [`MAX_SEARCH_SHARDS`](crate::enumerate::MAX_SEARCH_SHARDS), or a
    /// `top_k` of zero (an empty report) are rejected rather than
    /// silently clamped. [`OptimizeSpecBuilder::build`] runs this at
    /// construction time; [`optimize`] re-runs it before any work, so a
    /// hand-mutated spec still fails fast and is never cached.
    pub fn validate(&self) -> Result<()> {
        if self.deadline_ms > MAX_DEADLINE_MS {
            return Err(Error::Coordinator(format!(
                "deadline_ms {} exceeds the {MAX_DEADLINE_MS} ms (24 h) cap; use 0 for no deadline",
                self.deadline_ms
            )));
        }
        if usize::try_from(self.budget).is_err() {
            return Err(Error::Coordinator(format!(
                "budget {} does not fit this platform's usize; use 0 for unlimited",
                self.budget
            )));
        }
        if self.shards > MAX_SEARCH_SHARDS {
            return Err(Error::Coordinator(format!(
                "shards {} exceeds MAX_SEARCH_SHARDS ({MAX_SEARCH_SHARDS}); use 0 for auto",
                self.shards
            )));
        }
        if self.top_k == 0 {
            return Err(Error::Coordinator(
                "top_k 0 requests an empty report; keep at least one row".into(),
            ));
        }
        if self.exec_threads > MAX_EXEC_THREADS {
            return Err(Error::Coordinator(format!(
                "exec_threads {} exceeds MAX_EXEC_THREADS ({MAX_EXEC_THREADS}); use 0 to skip \
                 the execution rehearsal",
                self.exec_threads
            )));
        }
        Ok(())
    }

    /// The coordinator's canonical cache key for this spec (ISSUE 8):
    /// the cache generation, an α-invariant hash of the *parsed* source
    /// ([`crate::dsl::intern::canonical_hash`]), and every non-source
    /// knob verbatim. Two specs get the same key iff their sources are
    /// α-equivalent modulo formatting (whitespace, comments, binder
    /// names) and every other field agrees — exactly the condition under
    /// which [`optimize`] produces the same report, which is what makes
    /// canonical cache hits and single-flight coalescing sound.
    ///
    /// Returns `None` when the source does not parse: such jobs cannot
    /// be keyed (or coalesced) and the coordinator runs them directly
    /// for their parse error.
    pub fn canonical_key(&self, generation: u64) -> Option<CanonicalKey> {
        let expr = dsl::parse(&self.source).ok()?;
        let mut inputs = self.inputs.clone();
        // Submission order of the shape bindings is irrelevant to the
        // pipeline (they populate a name-keyed env); sort stably so it
        // is irrelevant to the key too. Duplicate names keep their
        // relative order — last-binding-wins stays part of the key.
        inputs.sort_by(|a, b| a.0.cmp(&b.0));
        Some(CanonicalKey {
            generation,
            source_hash: crate::dsl::intern::canonical_hash(&expr),
            inputs,
            rank_by: self.rank_by,
            subdivide_rnz: self.subdivide_rnz,
            top_k: self.top_k,
            prune: self.prune,
            verify: self.verify,
            budget: self.budget,
            deadline_ms: self.deadline_ms,
            shards: self.shards,
            exec_threads: self.exec_threads,
        })
    }
}

/// Builder for [`OptimizeSpec`] — the typed construction path (ISSUE 9).
/// Setters are chainable; [`build`](Self::build) validates the knob
/// bounds ([`OptimizeSpec::validate`]) and returns the spec or a typed
/// [`Error::Coordinator`] naming the offending field.
///
/// ```
/// use hofdla::coordinator::{OptimizeSpec, RankBy};
/// let spec = OptimizeSpec::builder("(rnz + * (in u) (in v))")
///     .input("u", &[64])
///     .input("v", &[64])
///     .rank_by(RankBy::CostModel)
///     .deadline_ms(250)
///     .build()
///     .unwrap();
/// assert_eq!(spec.top_k, 12);
/// ```
#[derive(Clone, Debug)]
pub struct OptimizeSpecBuilder {
    spec: OptimizeSpec,
}

impl OptimizeSpecBuilder {
    /// Append one named input with its row-major shape.
    pub fn input(mut self, name: impl Into<String>, shape: &[usize]) -> Self {
        self.spec.inputs.push((name.into(), shape.to_vec()));
        self
    }

    /// Replace the whole input list (submission order is irrelevant —
    /// the canonical key sorts by name).
    pub fn inputs(mut self, inputs: Vec<(String, Vec<usize>)>) -> Self {
        self.spec.inputs = inputs;
        self
    }

    /// Ranking metric ([`OptimizeSpec::rank_by`]).
    pub fn rank_by(mut self, rank_by: RankBy) -> Self {
        self.spec.rank_by = rank_by;
        self
    }

    /// Subdivide every reduction with this block size
    /// ([`OptimizeSpec::subdivide_rnz`]); pass `None` to disable.
    pub fn subdivide_rnz(mut self, b: impl Into<Option<usize>>) -> Self {
        self.spec.subdivide_rnz = b.into();
        self
    }

    /// Report rows to keep ([`OptimizeSpec::top_k`]; must be ≥ 1).
    pub fn top_k(mut self, top_k: usize) -> Self {
        self.spec.top_k = top_k;
        self
    }

    /// Branch-and-bound pruning ([`OptimizeSpec::prune`]).
    pub fn prune(mut self, prune: bool) -> Self {
        self.spec.prune = prune;
        self
    }

    /// Verify the winner's lowered program ([`OptimizeSpec::verify`]).
    pub fn verify(mut self, verify: bool) -> Self {
        self.spec.verify = verify;
        self
    }

    /// Anytime node budget ([`OptimizeSpec::budget`]; `0` = unlimited).
    pub fn budget(mut self, budget: u64) -> Self {
        self.spec.budget = budget;
        self
    }

    /// Wall-clock deadline in ms ([`OptimizeSpec::deadline_ms`];
    /// `0` = unlimited, capped at [`MAX_DEADLINE_MS`]).
    pub fn deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.spec.deadline_ms = deadline_ms;
        self
    }

    /// Search shard fan-out ([`OptimizeSpec::shards`]; `0` = auto,
    /// capped at [`MAX_SEARCH_SHARDS`](crate::enumerate::MAX_SEARCH_SHARDS)).
    pub fn shards(mut self, shards: usize) -> Self {
        self.spec.shards = shards;
        self
    }

    /// See [`OptimizeSpec::exec_threads`].
    pub fn exec_threads(mut self, exec_threads: usize) -> Self {
        self.spec.exec_threads = exec_threads;
        self
    }

    /// Validate the knob bounds and return the finished spec.
    pub fn build(self) -> Result<OptimizeSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

/// Canonical identity of an optimize request — what the coordinator's
/// result LRU and single-flight table key on. See
/// [`OptimizeSpec::canonical_key`] for the construction and the
/// soundness argument; `generation` is the flush/cost-model stamp that
/// makes invalidation free (old-generation keys simply stop matching).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CanonicalKey {
    /// Cache generation at keying time
    /// ([`crate::coordinator::Coordinator::flush_opt_cache`]).
    pub generation: u64,
    /// α-invariant hash of the parsed source.
    pub source_hash: u64,
    /// Input shapes, sorted stably by name.
    pub inputs: Vec<(String, Vec<usize>)>,
    pub rank_by: RankBy,
    pub subdivide_rnz: Option<usize>,
    pub top_k: usize,
    pub prune: bool,
    pub verify: bool,
    pub budget: u64,
    pub deadline_ms: u64,
    /// Part of the key although the search *result* is shard-width
    /// invariant (the deterministic-merge contract): the cached
    /// [`OptimizeResult::stats`] describe the run that produced them
    /// (effective shard count, per-shard extraction layout), and the
    /// "every non-source knob" key contract (ISSUE 8) stays trivially
    /// true.
    pub shards: usize,
    /// Execution-rehearsal width: cached results carry the rehearsal
    /// report of the run that produced them, so the knob is part of the
    /// key like every other non-source knob.
    pub exec_threads: usize,
}

/// The pipeline's report.
#[derive(Clone, Debug)]
pub struct OptimizeResult {
    pub variants_explored: usize,
    /// (display key, score) sorted ascending (best first).
    pub ranking: Vec<(String, f64)>,
    /// Display key of the winner.
    pub best: String,
    /// Pretty-printed winning expression.
    pub best_expr: String,
    /// Total input elements (diagnostic; ties results to requests).
    pub input_elems: usize,
    /// Counters from the enumeration BFS (expansion, pruning, bound
    /// tightenings, per-shard extraction counts). The coordinator folds
    /// these into its service [`super::Metrics`] per fresh pipeline run.
    pub stats: SearchStats,
    /// Programs that passed static footprint verification during this run
    /// (1 when the spec's `verify` knob is on — the winner — else 0).
    /// Folded into [`super::Metrics::verify_passed`].
    pub programs_verified: usize,
    /// Certified optimality gap of the search
    /// ([`SearchStats::certified_gap`]): `1.0` means the reported winner
    /// is exhaustively optimal under the ranking metric; `g > 1.0` means
    /// a budget/deadline/limit truncated the search and the true optimum
    /// can be at most `g×` better than the reported winner. `+∞` when a
    /// truncated run had nothing to certify (CacheSim jobs rank outside
    /// the search, so only complete runs certify there).
    pub certified_gap: f64,
    /// Execution-rehearsal report (`None` unless the spec's
    /// [`exec_threads`](OptimizeSpec::exec_threads) knob is on): how the
    /// winner actually ran, plus the parallel/serial loop split of its
    /// dependence certificate ([`crate::verify::ParCert`]).
    pub exec: Option<ExecRehearsal>,
}

/// Outcome of the optional execution rehearsal: the winner's lowered
/// program was run on deterministic synthetic inputs, threaded when its
/// certificate allows, and checked bit-identical to the serial path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecRehearsal {
    /// Root loops executed through the threaded path (0 or 1 per run).
    pub parallel_loops: u64,
    /// True when threads were requested but the certificate (or program
    /// shape) forced the fail-closed serial path.
    pub serial_fallback: bool,
    /// Worker threads the executor actually used.
    pub threads_used: usize,
    /// `MapLoop`s in the winner's certificate with a `Parallel` verdict.
    pub cert_parallel_loops: usize,
    /// `MapLoop`s demoted to `Serial` (with a named reason) in the cert.
    pub cert_serial_loops: usize,
}

/// Per-job runtime control the service front end threads into a pipeline
/// run (ISSUE 9): an external cancellation token (flipped by
/// [`OptimizeHandle::cancel`](crate::coordinator::OptimizeHandle::cancel)
/// while the search runs) and the job's deadline origin — the instant the
/// request *entered the service*, so measured queue wait is charged
/// against the deadline budget rather than restarting the clock when a
/// worker finally picks the job up.
///
/// [`Default`] (no token, origin = pipeline entry) reproduces the plain
/// [`optimize`] behavior exactly.
#[derive(Clone, Debug, Default)]
pub struct JobCtl {
    /// External cancellation, forwarded to
    /// [`SearchOptions::cancel`](crate::enumerate::SearchOptions::cancel).
    pub cancel: Option<CancelToken>,
    /// When the job's [`OptimizeSpec::deadline_ms`] started counting.
    /// `None` = pipeline entry (the library-call convention).
    pub deadline_origin: Option<std::time::Instant>,
}

/// Run the pipeline synchronously.
///
/// Equivalent to [`optimize_ctl`] with a default [`JobCtl`]: no external
/// cancellation, deadline measured from pipeline entry.
pub fn optimize(spec: &OptimizeSpec) -> Result<OptimizeResult> {
    optimize_ctl(spec, &JobCtl::default())
}

/// Run the pipeline synchronously under per-job runtime control: the
/// coordinator's workers call this with the job's [`CancelToken`] and its
/// service-intake timestamp ([`JobCtl`]), so a running search can be
/// cancelled mid-wave from the handle and queue wait counts against the
/// deadline.
pub fn optimize_ctl(spec: &OptimizeSpec, ctl: &JobCtl) -> Result<OptimizeResult> {
    // The deadline clock starts at the job's service-intake instant when
    // the caller provides one, else at pipeline entry — parse/fuse/
    // subdivide time counts against it either way, as a service caller
    // would expect. A job whose queue wait already consumed its whole
    // deadline truncates at the search's first checkpoint and returns
    // the start variant with `deadline_hit` set.
    let entered = std::time::Instant::now();
    let origin = ctl.deadline_origin.unwrap_or(entered);
    spec.validate()?;
    let expr = dsl::parse(&spec.source)?;
    let mut env = Env::new();
    let mut input_elems = 0usize;
    for (name, shape) in &spec.inputs {
        let layout = Layout::row_major(shape);
        input_elems += layout.len();
        env.inputs.insert(name.clone(), layout);
    }
    crate::typecheck::infer(&expr, &env)?;

    // Fuse pipelines so the executor's normal form holds.
    let fused = fusion::fuse(&expr);
    let ctx = Ctx::new(env.clone());

    // Optional subdivision of every reduction (innermost-first so the
    // spine stays well-labelled).
    let (start_expr, labels) = match spec.subdivide_rnz {
        None => (fused.clone(), spine_labels(&fused)?),
        Some(b) => {
            let subdivided = subdivide_deepest_rnz(&fused, b, &ctx)?;
            // Bring subdivided bound-variable views back to the input level
            // (the paper's A^(1a)-style bookkeeping) so exchange rules can
            // traverse the spine.
            let hoisted = crate::rewrite::rewrite_bottom_up(
                &[subdivision::hoist_subdiv()],
                &subdivided,
            );
            let normalized = normalize(&hoisted);
            let labels = spine_labels(&normalized)?;
            (normalized, labels)
        }
    };
    let label_refs: Vec<&str> = labels.iter().map(|s| s.as_str()).collect();
    let start = Variant::new(start_expr, &label_refs);

    // Sharded, id-native BFS; cost-model scores come back with the
    // variants, so the CostModel ranking below is free.
    // The branch-and-bound cut maintains a cost-model bound; for CacheSim
    // jobs those scores are discarded (the simulator re-ranks the kept
    // variants), so enabling it there would only add per-candidate
    // lower+estimate work. The knob therefore applies to cost-model
    // ranking only.
    let cost_ranked = matches!(spec.rank_by, RankBy::CostModel);
    let opts = SearchOptions {
        limit: 4096,
        // 0 = auto: fan one job out across the available cores.
        shards: spec.shards,
        prune_slack: if spec.prune && cost_ranked {
            Some(DEFAULT_PRUNE_SLACK)
        } else {
            None
        },
        score: cost_ranked,
        budget: usize::try_from(spec.budget).unwrap_or(usize::MAX),
        deadline: (spec.deadline_ms > 0)
            .then(|| origin + std::time::Duration::from_millis(spec.deadline_ms)),
        cancel: ctl.cancel.clone(),
    };
    let SearchResult {
        variants,
        scores: bfs_scores,
        stats,
    } = enumerate_search(&start, &ctx, &opts)?;
    let scores = match spec.rank_by {
        RankBy::CostModel if bfs_scores.len() == variants.len() => bfs_scores,
        _ => rank_variants(&variants, &env, spec.rank_by)?,
    };
    let mut ranking: Vec<(String, f64)> = variants
        .iter()
        .zip(&scores)
        .map(|(v, &s)| (v.display_key(), s))
        .collect();
    // Winner: the first variant attaining the minimum score (matches the
    // serial path's tie-breaking).
    let mut best_expr: Option<(f64, &dsl::Expr)> = None;
    for (v, &score) in variants.iter().zip(&scores) {
        best_expr = match best_expr {
            None => Some((score, &v.expr)),
            Some((s, _)) if score < s => Some((score, &v.expr)),
            keep => keep,
        };
    }
    ranking.sort_by(|a, b| a.1.total_cmp(&b.1));
    // Unlowerable variants rank last with score +∞, so one bad
    // rearrangement cannot fail the whole job (unlike the seed path) —
    // but when *nothing* lowers there is no executable winner to report.
    if ranking.first().map_or(false, |(_, s)| s.is_infinite()) {
        return Err(Error::Lower(
            "no enumerated variant lowers (is the program fully fused?)".into(),
        ));
    }
    let variants_explored = ranking.len();
    // validate() rejected top_k == 0, so the winner row always survives.
    ranking.truncate(spec.top_k);
    let (_, best_e) =
        best_expr.ok_or_else(|| Error::Rewrite("no variants produced".into()))?;
    // Production verification gate: prove the winner's lowered program
    // in-bounds, initialized and disjoint before reporting it. (Debug
    // builds already verified every candidate inside `lower`; this makes
    // the winner's certificate unconditional.)
    let programs_verified = if spec.verify {
        let prog = lower(best_e, &env)?;
        crate::verify::verify(&prog)?;
        1
    } else {
        0
    };
    let exec = rehearse_execution(best_e, &env, spec.exec_threads)?;
    let certified_gap = stats.certified_gap;
    Ok(OptimizeResult {
        variants_explored,
        best: ranking[0].0.clone(),
        best_expr: dsl::pretty(best_e),
        ranking,
        input_elems,
        stats,
        programs_verified,
        certified_gap,
        exec,
    })
}

/// Execution rehearsal: lower the winner, run it on deterministic
/// synthetic inputs (sized from its declared input lengths) and — for
/// `threads >= 2` — run it again through the certificate-gated threaded
/// executor and require the two outputs bit-identical. Returns `None`
/// when the knob is off (`threads == 0`).
fn rehearse_execution(
    best: &dsl::Expr,
    env: &Env,
    threads: usize,
) -> Result<Option<ExecRehearsal>> {
    if threads == 0 {
        return Ok(None);
    }
    let prog = lower(best, env)?;
    let fp = crate::verify::verify(&prog)?;
    // Deterministic, slot-keyed synthetic inputs: mixed-sign, non-constant
    // values so element misplacement cannot cancel out.
    let owned: Vec<Vec<f64>> = prog
        .input_lens
        .iter()
        .enumerate()
        .map(|(slot, &len)| {
            (0..len)
                .map(|i| ((i * 7 + slot * 13) % 31) as f64 * 0.25 - 3.0)
                .collect()
        })
        .collect();
    let bufs: Vec<&[f64]> = owned.iter().map(|v| v.as_slice()).collect();
    let mut serial = vec![0.0; prog.out_size];
    execute(&prog, &bufs, &mut serial)?;
    let report = if threads >= 2 {
        let mut threaded = vec![0.0; prog.out_size];
        let rep = execute_threaded(&prog, &bufs, &mut threaded, threads)?;
        if serial
            .iter()
            .zip(&threaded)
            .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(Error::Coordinator(
                "execution rehearsal: threaded output diverged from serial — \
                 refusing to report an unsound parallel certificate"
                    .into(),
            ));
        }
        rep
    } else {
        ExecReport { parallel_loops: 0, serial_fallback: false, threads_used: 1 }
    };
    Ok(Some(ExecRehearsal {
        parallel_loops: report.parallel_loops,
        serial_fallback: report.serial_fallback,
        threads_used: report.threads_used,
        cert_parallel_loops: fp.par.parallel_loops(),
        cert_serial_loops: fp.par.serial_loops(),
    }))
}

/// Score one variant under the chosen metric.
fn score_one(v: &Variant, env: &Env, rank_by: RankBy) -> Result<f64> {
    let prog = lower(&v.expr, env)?;
    Ok(match rank_by {
        RankBy::CostModel => estimate(&prog).score(),
        RankBy::CacheSim => simulate(&prog, &HierarchyConfig::cpu_i5_7300hq())?.cost_cycles(),
    })
}

/// Rank all variants, fanning the work out across scoped threads when the
/// job is heavy enough to amortize spawning (cache simulation is always
/// heavy; analytic cost-model scoring only pays off for large variant
/// sets). Scores come back in variant order; the first error (by variant
/// index) is reported, as on the serial path.
fn rank_variants(variants: &[Variant], env: &Env, rank_by: RankBy) -> Result<Vec<f64>> {
    let n = variants.len();
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let heavy = matches!(rank_by, RankBy::CacheSim) || n >= 32;
    // Cap the per-job fan-out: several coordinator workers may each be
    // ranking at once, and hw threads per job would oversubscribe the
    // machine workers-fold.
    let threads = if heavy { hw.min(n).min(4) } else { 1 };
    if threads <= 1 {
        return variants.iter().map(|v| score_one(v, env, rank_by)).collect();
    }
    let chunk = (n + threads - 1) / threads;
    let per_chunk: Vec<Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = variants
            .chunks(chunk)
            .map(|slice| {
                s.spawn(move || {
                    slice
                        .iter()
                        .map(|v| score_one(v, env, rank_by))
                        .collect::<Result<Vec<f64>>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::Coordinator("ranking thread panicked".into())))
            })
            .collect()
    });
    let mut out = Vec::with_capacity(n);
    for c in per_chunk {
        out.extend(c?);
    }
    Ok(out)
}

/// Default spine labels: map1, map2, …, rnz1, … by kind and order.
fn spine_labels(e: &dsl::Expr) -> Result<Vec<String>> {
    let kinds = crate::enumerate::spine_kinds(e);
    if kinds.is_empty() {
        return Err(Error::Rewrite("expression has no HoF spine".into()));
    }
    let mut map_n = 0usize;
    let mut rnz_n = 0usize;
    Ok(kinds
        .iter()
        .map(|k| {
            if *k == "map" {
                map_n += 1;
                format!("map{map_n}")
            } else {
                rnz_n += 1;
                format!("rnz{rnz_n}")
            }
        })
        .collect())
}

/// Subdivide the deepest `rnz` on the spine (the paper's Table 2 starting
/// move), then leave rearrangement to the enumerator.
fn subdivide_deepest_rnz(e: &dsl::Expr, b: usize, ctx: &Ctx) -> Result<dsl::Expr> {
    use dsl::Expr;
    fn rec(e: &Expr, b: usize, ctx: &Ctx) -> Result<Expr> {
        match e {
            Expr::Nzip { f, args } => {
                let Expr::Lam { params, body } = &**f else {
                    return Err(Error::Rewrite("nzip operator is not a lambda".into()));
                };
                let mut ctx2 = ctx.clone();
                for (p, a) in params.iter().zip(args) {
                    ctx2.vars.insert(p.clone(), ctx.layout_of(a)?.peel_outer()?);
                }
                let new_body = rec(body, b, &ctx2)?;
                Ok(Expr::Nzip {
                    f: Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    }),
                    args: args.clone(),
                })
            }
            Expr::Rnz { .. } => subdivision::subdivide_rnz(e, b, ctx).ok_or_else(|| {
                Error::Rewrite(format!("cannot subdivide reduction with block {b}"))
            }),
            other => Err(Error::Rewrite(format!(
                "no reduction on the spine: {}",
                dsl::pretty(other)
            ))),
        }
    }
    rec(e, b, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matmul_spec(n: usize, rank_by: RankBy) -> OptimizeSpec {
        OptimizeSpec::builder(
            "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
        )
        .input("A", &[n, n])
        .input("B", &[n, n])
        .rank_by(rank_by)
        .top_k(10)
        // Exercise the production verification gate on every pipeline
        // test: the winner must carry a footprint certificate.
        .verify(true)
        .build()
        .unwrap()
    }

    #[test]
    fn pipeline_finds_table1_winner_by_cost_model() {
        let r = optimize(&matmul_spec(32, RankBy::CostModel)).unwrap();
        assert_eq!(r.variants_explored, 6);
        assert_eq!(r.best, "map1 rnz map2"); // mapA rnz mapB
    }

    #[test]
    fn pipeline_finds_table1_winner_by_cachesim() {
        // needs matrices larger than L1 for the ordering to show
        let r = optimize(&matmul_spec(128, RankBy::CacheSim)).unwrap();
        assert_eq!(r.variants_explored, 6);
        assert_eq!(r.best, "map1 rnz map2");
    }

    #[test]
    fn pipeline_with_subdivision_explores_twelve() {
        let mut spec = matmul_spec(32, RankBy::CostModel);
        spec.subdivide_rnz = Some(4);
        let r = optimize(&spec).unwrap();
        assert_eq!(r.variants_explored, 12); // Table 2
    }

    #[test]
    fn pruned_pipeline_matches_exhaustive_on_subdivided_matmul() {
        // ISSUE 5 acceptance: on the n=64 / b=4 matmul workload the
        // pruned + sharded search actually cuts (`pruned > 0` at the
        // default slack) and still returns the same best variant — same
        // key, same expression — as exhaustive mode; every surviving
        // entry keeps its exhaustive score.
        let mut exhaustive = matmul_spec(64, RankBy::CostModel);
        exhaustive.subdivide_rnz = Some(4);
        // Keep the whole family in the report: the survivor-score check
        // below looks every pruned survivor up in the exhaustive ranking,
        // and survivorship follows the lower bound, not the rank — a
        // truncated report could miss a legitimately-surviving tail entry.
        exhaustive.top_k = 12;
        let mut pruned = exhaustive.clone();
        pruned.prune = true;
        let a = optimize(&exhaustive).unwrap();
        let b = optimize(&pruned).unwrap();
        assert_eq!(a.variants_explored, 12); // Table 2
        assert_eq!(a.best, b.best);
        // Same winner *program*: binder names are gensym'd per run, so
        // compare the (name-free) lowered form, not the pretty string.
        let env = Env::new()
            .with("A", Layout::row_major(&[64, 64]))
            .with("B", Layout::row_major(&[64, 64]));
        let lower_best = |r: &OptimizeResult| {
            format!("{:?}", lower(&dsl::parse(&r.best_expr).unwrap(), &env).unwrap())
        };
        assert_eq!(lower_best(&a), lower_best(&b), "winner program diverged");
        // The rearrangement-sensitive bound makes the default-slack cut
        // fire: dominated rearrangements leave the report before being
        // lowered or scored.
        assert!(b.stats.pruned > 0, "default-slack cut must fire");
        assert!(b.variants_explored < a.variants_explored);
        // The pruned ranking is the exhaustive ranking restricted to the
        // survivors: same winner first, bit-identical scores throughout.
        let full: std::collections::HashMap<&str, f64> =
            a.ranking.iter().map(|(k, s)| (k.as_str(), *s)).collect();
        assert_eq!(a.ranking[0], b.ranking[0]);
        for (k, s) in &b.ranking {
            assert_eq!(full[k.as_str()], *s, "{k}: score changed under pruning");
        }
        // Cut candidates are never extracted; kept candidates once, at
        // the output boundary.
        assert!(b.stats.extracted() < a.stats.extracted());
        assert!(a.stats.extracted() > 0);
        assert!(a.stats.expanded > 0);
    }

    #[test]
    fn pipeline_fuses_before_enumerating() {
        // an unfused pipeline over vectors: map f (map g v) reduced
        let spec = OptimizeSpec::builder("(rnz + * (map (lam (x) (app * x 2.0)) (in u)) (in v))")
            .input("u", &[64])
            .input("v", &[64])
            .top_k(3)
            .build()
            .unwrap();
        let r = optimize(&spec).unwrap();
        assert_eq!(r.variants_explored, 1); // single rnz after fusion
        assert!(r.best_expr.starts_with("(rnz"));
        assert_eq!(r.programs_verified, 0, "verify knob off");
    }

    #[test]
    fn verify_knob_certifies_the_winner() {
        let mut spec = matmul_spec(16, RankBy::CostModel);
        spec.subdivide_rnz = Some(4);
        let r = optimize(&spec).unwrap();
        assert_eq!(r.programs_verified, 1);
    }

    #[test]
    fn exec_rehearsal_runs_threaded_and_reports_cert_split() {
        // ISSUE 10: with the knob on, the winner is lowered and *run* —
        // threaded when its certificate allows — and the report carries
        // both what happened and the cert's parallel/serial loop split.
        let mut spec = matmul_spec(16, RankBy::CostModel);
        spec.subdivide_rnz = Some(4);
        spec.exec_threads = 2;
        let ex = optimize(&spec).unwrap().exec.expect("rehearsal requested");
        assert_eq!(ex.parallel_loops, 1, "matmul roots in a certified map");
        assert!(!ex.serial_fallback);
        assert_eq!(ex.threads_used, 2);
        assert!(ex.cert_parallel_loops >= 1);
        // `1` rehearses serially (no threaded run, no fallback flag).
        spec.exec_threads = 1;
        let ex = optimize(&spec).unwrap().exec.unwrap();
        assert_eq!((ex.parallel_loops, ex.threads_used), (0, 1));
        assert!(!ex.serial_fallback);
        // Off (the default) skips the rehearsal entirely.
        spec.exec_threads = 0;
        assert!(optimize(&spec).unwrap().exec.is_none());
    }

    #[test]
    fn unknown_input_is_an_error() {
        let mut spec = matmul_spec(8, RankBy::CostModel);
        spec.inputs.pop();
        assert!(optimize(&spec).is_err());
    }

    #[test]
    fn unlimited_jobs_report_gap_exactly_one() {
        let r = optimize(&matmul_spec(16, RankBy::CostModel)).unwrap();
        assert_eq!(r.certified_gap, 1.0);
        assert!(r.stats.complete);
    }

    #[test]
    fn budget_truncated_job_returns_winner_with_sound_gap() {
        // ISSUE 7 acceptance: a budget-truncated run returns a winner
        // plus a certified gap ≥ 1.0 that soundly bounds the true
        // optimum (known from the exhaustive run of the same spec).
        let mut spec = matmul_spec(64, RankBy::CostModel);
        spec.subdivide_rnz = Some(4);
        spec.top_k = 12;
        let full = optimize(&spec).unwrap();
        assert_eq!(full.certified_gap, 1.0);
        let true_opt = full.ranking[0].1;
        spec.budget = 2;
        let truncated = optimize(&spec).unwrap();
        assert!(truncated.stats.budget_hit);
        assert!(!truncated.stats.complete);
        assert!(truncated.certified_gap > 1.0);
        assert!(truncated.certified_gap.is_finite());
        assert!(truncated.variants_explored < full.variants_explored);
        // Soundness: the truncated winner is within the certified factor
        // of the true optimum.
        assert!(truncated.ranking[0].1 <= truncated.certified_gap * true_opt);
    }

    #[test]
    fn generous_deadline_leaves_search_complete() {
        let mut spec = matmul_spec(16, RankBy::CostModel);
        spec.deadline_ms = MAX_DEADLINE_MS;
        let r = optimize(&spec).unwrap();
        assert!(r.stats.complete && !r.stats.deadline_hit);
        assert_eq!(r.certified_gap, 1.0);
    }

    #[test]
    fn nonsense_deadline_is_rejected_not_clamped() {
        let mut spec = matmul_spec(8, RankBy::CostModel);
        spec.deadline_ms = MAX_DEADLINE_MS + 1;
        let err = optimize(&spec).unwrap_err().to_string();
        assert!(err.contains("deadline_ms"), "{err}");
    }

    #[test]
    fn builder_validates_at_build_time() {
        // Each out-of-bounds knob is caught by `.build()` — before the
        // spec can be submitted, queued, or keyed — with a typed error
        // naming the offending field.
        let base = || {
            OptimizeSpec::builder("(rnz + * (in u) (in v))")
                .input("u", &[8])
                .input("v", &[8])
        };
        let err = base().deadline_ms(MAX_DEADLINE_MS + 1).build().unwrap_err();
        assert!(err.to_string().contains("deadline_ms"), "{err}");
        let err = base().shards(MAX_SEARCH_SHARDS + 1).build().unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
        let err = base().top_k(0).build().unwrap_err();
        assert!(err.to_string().contains("top_k"), "{err}");
        let err = base()
            .exec_threads(crate::exec::MAX_EXEC_THREADS + 1)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("exec_threads"), "{err}");
        #[cfg(target_pointer_width = "32")]
        {
            let err = base().budget(u64::MAX).build().unwrap_err();
            assert!(err.to_string().contains("budget"), "{err}");
        }
        // In-bounds knobs build, and the builder's field routing is 1:1.
        let spec = base()
            .rank_by(RankBy::CacheSim)
            .subdivide_rnz(4)
            .top_k(5)
            .prune(true)
            .verify(true)
            .budget(100)
            .deadline_ms(250)
            .shards(2)
            .exec_threads(4)
            .build()
            .unwrap();
        assert_eq!(spec.rank_by, RankBy::CacheSim);
        assert_eq!(spec.subdivide_rnz, Some(4));
        assert_eq!(spec.top_k, 5);
        assert!(spec.prune && spec.verify);
        assert_eq!((spec.budget, spec.deadline_ms, spec.shards), (100, 250, 2));
        assert_eq!(spec.exec_threads, 4);
        // `inputs` replaces wholesale; `input` appends.
        let spec = base()
            .inputs(vec![("w".into(), vec![4])])
            .input("x", &[2])
            .build()
            .unwrap();
        assert_eq!(spec.inputs, vec![("w".into(), vec![4]), ("x".into(), vec![2])]);
    }

    #[test]
    fn explicit_shards_reproduce_auto_result_bit_identically() {
        // The acceptance criterion's uncancelled half, at the pipeline
        // level: the winner path is bit-identical across explicit shard
        // widths 1/2/8 (the service-level parity test in `service_props`
        // rides on this).
        let mut spec = matmul_spec(32, RankBy::CostModel);
        spec.subdivide_rnz = Some(4);
        let auto = optimize(&spec).unwrap();
        for shards in [1usize, 2, 8] {
            let mut s = spec.clone();
            s.shards = shards;
            let r = optimize(&s).unwrap();
            assert_eq!(r.best, auto.best, "shards={shards}: winner key diverged");
            assert_eq!(r.ranking, auto.ranking, "shards={shards}: ranking diverged");
            assert_eq!(
                r.stats.extracted_per_shard.len(),
                shards,
                "shards={shards}: explicit width must be honored"
            );
        }
    }

    #[test]
    fn pre_cancelled_job_stops_at_first_checkpoint() {
        // A token cancelled before the search starts stops expansion at
        // the first between-wave checkpoint: stats report an external
        // cancel (not a completed frontier), and the job still returns
        // its best-so-far (the start variant) rather than erroring.
        let token = CancelToken::new();
        token.cancel();
        let mut spec = matmul_spec(16, RankBy::CostModel);
        spec.subdivide_rnz = Some(4);
        let ctl = JobCtl {
            cancel: Some(token),
            deadline_origin: None,
        };
        let r = optimize_ctl(&spec, &ctl).unwrap();
        assert!(r.stats.cancelled, "external token must be attributed");
        assert!(!r.stats.complete);
        assert!(r.variants_explored < 12, "search must stop early");
    }

    #[test]
    fn deadline_origin_in_the_past_charges_queue_wait() {
        // Deadline-minus-queue-wait accounting: an origin far enough in
        // the past that the 1 ms deadline is already spent truncates the
        // search at its first checkpoint with `deadline_hit` set.
        let mut spec = matmul_spec(16, RankBy::CostModel);
        spec.subdivide_rnz = Some(4);
        spec.deadline_ms = 1;
        let ctl = JobCtl {
            cancel: None,
            deadline_origin: Some(std::time::Instant::now() - std::time::Duration::from_secs(2)),
        };
        let r = optimize_ctl(&spec, &ctl).unwrap();
        assert!(r.stats.deadline_hit, "queue wait must count against the deadline");
        assert!(!r.stats.complete);
    }

    #[test]
    fn canonical_key_is_alpha_and_format_invariant() {
        let spec = matmul_spec(16, RankBy::CostModel);
        let mut renamed = spec.clone();
        renamed.source =
            "(map (lam (rowOfA) (map (lam (colOfB) (rnz + * rowOfA colOfB)) \
             (flip 0 (in B)))) (in A))"
                .into();
        let mut reformatted = spec.clone();
        reformatted.source = format!(
            "  ; matmul, reformatted\n{}\n",
            spec.source.replace(") (", ")\n  (")
        );
        let k = spec.canonical_key(7).unwrap();
        assert_eq!(k, renamed.canonical_key(7).unwrap());
        assert_eq!(k, reformatted.canonical_key(7).unwrap());
        // Input submission order is canonicalized away…
        let mut flipped = spec.clone();
        flipped.inputs.reverse();
        assert_eq!(k, flipped.canonical_key(7).unwrap());
        // …but generation, shapes and knobs are load-bearing.
        assert_ne!(k, spec.canonical_key(8).unwrap());
        assert_ne!(k, matmul_spec(32, RankBy::CostModel).canonical_key(7).unwrap());
        assert_ne!(k, matmul_spec(16, RankBy::CacheSim).canonical_key(7).unwrap());
        let mut subdivided = spec.clone();
        subdivided.subdivide_rnz = Some(4);
        assert_ne!(k, subdivided.canonical_key(7).unwrap());
        let mut threaded = spec.clone();
        threaded.exec_threads = 2;
        assert_ne!(k, threaded.canonical_key(7).unwrap());
    }

    #[test]
    fn canonical_key_distinguishes_free_names_and_unparseable_is_none() {
        let spec = matmul_spec(16, RankBy::CostModel);
        // Renaming an *input* (free name) is a different kernel.
        let mut other = spec.clone();
        other.source = spec.source.replace("(in A)", "(in C)");
        other.inputs[0].0 = "C".into();
        assert_ne!(
            spec.canonical_key(1).unwrap().source_hash,
            other.canonical_key(1).unwrap().source_hash
        );
        let mut bad = spec;
        bad.source = "(map (lam".into();
        assert!(bad.canonical_key(1).is_none());
    }
}
