//! Service metrics: cheap atomic counters surfaced by the CLI's `serve`
//! status output and asserted on by the invariant tests.

use std::sync::atomic::{AtomicU64, Ordering};

/// Counters for the whole service lifetime.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Artifact-execution batches drained by the runtime thread.
    pub exec_batches: AtomicU64,
    /// Largest batch the runtime thread has seen.
    pub max_batch_seen: AtomicU64,
    /// Executable-cache hits on the runtime thread.
    pub exec_cache_hits: AtomicU64,
    /// Optimize jobs answered from the coordinator's result LRU.
    pub opt_cache_hits: AtomicU64,
    /// Generation advances of the optimize-result cache
    /// ([`crate::coordinator::Coordinator::flush_opt_cache`]).
    pub opt_cache_flushes: AtomicU64,
}

impl Metrics {
    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} exec_batches={} max_batch={} cache_hits={} opt_cache_hits={} opt_cache_flushes={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.exec_batches.load(Ordering::Relaxed),
            self.max_batch_seen.load(Ordering::Relaxed),
            self.exec_cache_hits.load(Ordering::Relaxed),
            self.opt_cache_hits.load(Ordering::Relaxed),
            self.opt_cache_flushes.load(Ordering::Relaxed),
        )
    }

    /// Jobs in flight (submitted minus resolved).
    pub fn in_flight(&self) -> u64 {
        self.submitted
            .load(Ordering::Relaxed)
            .saturating_sub(
                self.completed.load(Ordering::Relaxed) + self.failed.load(Ordering::Relaxed),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_and_in_flight() {
        let m = Metrics::default();
        m.submitted.store(5, Ordering::Relaxed);
        m.completed.store(3, Ordering::Relaxed);
        m.failed.store(1, Ordering::Relaxed);
        assert_eq!(m.in_flight(), 1);
        assert!(m.summary().contains("submitted=5"));
    }
}
