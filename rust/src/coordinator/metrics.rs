//! Service metrics: cheap atomic counters surfaced by the CLI's `serve`
//! status output and asserted on by the invariant tests.
//!
//! Besides the job-lifecycle counters, the coordinator folds each fresh
//! optimize run's [`SearchStats`] into the `search_*` aggregates (cache
//! hits do not re-record — the counters describe work actually performed),
//! so pruning effectiveness and the no-extraction invariant of the
//! candidate score path are observable on production traffic.

use crate::enumerate::SearchStats;
use std::sync::atomic::{AtomicU64, Ordering};

/// Counters for the whole service lifetime.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Artifact-execution batches drained by the runtime thread.
    pub exec_batches: AtomicU64,
    /// Largest batch the runtime thread has seen.
    pub max_batch_seen: AtomicU64,
    /// Executable-cache hits on the runtime thread.
    pub exec_cache_hits: AtomicU64,
    /// Optimize jobs answered from the result LRU by a spec whose source
    /// text matched the cached entry byte-for-byte.
    pub opt_cache_hits_exact: AtomicU64,
    /// Optimize jobs answered from the result LRU by an α-equivalent or
    /// reformatted source of the cached kernel (same
    /// [`crate::coordinator::CanonicalKey`], different text) — the
    /// cross-request sharing the canonical key exists to capture.
    pub opt_cache_hits_canonical: AtomicU64,
    /// Optimize jobs that found an identical request already in flight
    /// and waited on its result instead of searching (single-flight).
    pub opt_coalesced: AtomicU64,
    /// Generation advances of the optimize-result cache
    /// ([`crate::coordinator::Coordinator::flush_opt_cache`]).
    pub opt_cache_flushes: AtomicU64,
    /// Gauge: peak concurrently checked-out [`SharedArena`]s from the
    /// process-wide pool ([`crate::dsl::intern::arena_pool_stats`]),
    /// refreshed after every fresh search — the pool's working set.
    ///
    /// [`SharedArena`]: crate::dsl::intern::SharedArena
    pub arena_pool_high_water: AtomicU64,
    /// BFS frontier parents expanded across all fresh optimize runs.
    pub search_expanded: AtomicU64,
    /// Exchange applications generated across all fresh optimize runs.
    pub search_generated: AtomicU64,
    /// Candidates cut by the lower-bound branch-and-bound.
    pub search_pruned: AtomicU64,
    /// Candidates dropped because they no longer typechecked.
    pub search_type_rejects: AtomicU64,
    /// Times a search's shared best-known score tightened.
    pub search_bound_updates: AtomicU64,
    /// `Box<Expr>` trees extracted from search arenas (output-boundary
    /// extraction of kept candidates; the score path contributes zero).
    pub search_extractions: AtomicU64,
    /// Fresh searches whose node budget stopped expansion before the
    /// frontier drained (anytime truncation; each such run reported a
    /// certified gap > 1.0).
    pub search_budget_hits: AtomicU64,
    /// Fresh searches stopped by their deadline (between waves or by
    /// cancelling an in-flight wave).
    pub search_deadline_hits: AtomicU64,
    /// Fresh searches stopped by an external [`CancelToken`]
    /// ([`crate::coordinator::OptimizeHandle::cancel`]) — the search was
    /// running when the client gave up on it.
    ///
    /// [`CancelToken`]: crate::enumerate::CancelToken
    pub search_cancelled: AtomicU64,
    /// Optimize jobs whose handle was cancelled while they were still
    /// queued: the worker dropped them at checkout without starting a
    /// search (counted in `failed`, never cached).
    pub cancelled_before_start: AtomicU64,
    /// Optimize jobs rejected at intake by admission control
    /// ([`crate::Error::Overloaded`]): the bounded queue was full. Shed
    /// jobs never count as `submitted` and never reach a worker.
    pub shed: AtomicU64,
    /// Gauge: optimize jobs currently waiting in the intake queue
    /// (excludes the job each worker is running).
    pub queue_depth: AtomicU64,
    /// Gauge: deepest the intake queue has ever been.
    pub queue_high_water: AtomicU64,
    /// Total nanoseconds optimize jobs spent queued before a worker
    /// picked them up (the wait that deadline propagation charges
    /// against each job's budget).
    pub queue_wait_ns_total: AtomicU64,
    /// Gauge: longest single queue wait observed, in nanoseconds.
    pub queue_wait_max_ns: AtomicU64,
    /// Intake batches checked out by workers (a batch is one leader plus
    /// the same-family followers drained with it; singletons count too).
    pub opt_batches: AtomicU64,
    /// Optimize jobs that rode in a batch of ≥ 2 — distinct same-family
    /// jobs sharing one pooled arena checkout sequentially.
    pub opt_batched_jobs: AtomicU64,
    /// Gauge: largest intake batch a worker has checked out.
    pub max_opt_batch: AtomicU64,
    /// Gauge: the certified optimality gap of the most recent fresh
    /// search, stored as `f64` bits (`0` = no search recorded yet). Read
    /// through [`Metrics::last_certified_gap`].
    pub last_gap_bits: AtomicU64,
    /// Winner programs that passed static footprint verification
    /// ([`crate::verify::verify`]) across fresh optimize runs with the
    /// spec's `verify` knob on.
    pub verify_passed: AtomicU64,
    /// Optimize jobs failed because a program was *rejected* by the
    /// verifier ([`crate::Error::Verify`]) — should stay 0; any tick is a
    /// lowering or rewrite bug caught before execution.
    pub verify_rejects: AtomicU64,
    /// Root loops executed through the certificate-gated threaded path
    /// ([`crate::exec::execute_threaded`]) across fresh optimize runs
    /// whose spec requested an execution rehearsal.
    pub exec_parallel_loops: AtomicU64,
    /// Execution rehearsals that requested threads but fell closed to the
    /// serial path (`Serial` certificate verdict or non-map root).
    pub exec_serial_fallback: AtomicU64,
    /// Gauge: most worker threads any single rehearsal actually used.
    pub exec_threads_high_water: AtomicU64,
}

impl Metrics {
    /// Fold one search run's counters into the service aggregates. Called
    /// by the optimize workers for fresh pipeline runs only, never for
    /// result-cache hits.
    pub fn record_search(&self, s: &SearchStats) {
        self.search_expanded
            .fetch_add(s.expanded as u64, Ordering::Relaxed);
        self.search_generated
            .fetch_add(s.generated as u64, Ordering::Relaxed);
        self.search_pruned.fetch_add(s.pruned as u64, Ordering::Relaxed);
        self.search_type_rejects
            .fetch_add(s.type_rejects as u64, Ordering::Relaxed);
        self.search_bound_updates
            .fetch_add(s.bound_updates as u64, Ordering::Relaxed);
        self.search_extractions
            .fetch_add(s.extracted(), Ordering::Relaxed);
        self.search_budget_hits
            .fetch_add(u64::from(s.budget_hit), Ordering::Relaxed);
        self.search_deadline_hits
            .fetch_add(u64::from(s.deadline_hit), Ordering::Relaxed);
        self.search_cancelled
            .fetch_add(u64::from(s.cancelled), Ordering::Relaxed);
        self.last_gap_bits
            .store(s.certified_gap.to_bits(), Ordering::Relaxed);
    }

    /// Record one job's measured queue wait (intake → worker checkout).
    pub fn record_queue_wait(&self, wait: std::time::Duration) {
        let ns = u64::try_from(wait.as_nanos()).unwrap_or(u64::MAX);
        self.queue_wait_ns_total.fetch_add(ns, Ordering::Relaxed);
        self.queue_wait_max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record one intake batch of `jobs` same-family optimize jobs
    /// checked out together by a worker.
    pub fn record_batch(&self, jobs: u64) {
        self.opt_batches.fetch_add(1, Ordering::Relaxed);
        if jobs >= 2 {
            self.opt_batched_jobs.fetch_add(jobs, Ordering::Relaxed);
        }
        self.max_opt_batch.fetch_max(jobs, Ordering::Relaxed);
    }

    /// Total optimize jobs answered from the result LRU, exact and
    /// canonical combined.
    pub fn opt_cache_hits(&self) -> u64 {
        self.opt_cache_hits_exact.load(Ordering::Relaxed)
            + self.opt_cache_hits_canonical.load(Ordering::Relaxed)
    }

    /// The certified optimality gap of the most recent fresh search:
    /// `1.0` = it ran to completion, `> 1.0` = truncated with that
    /// certified bound, `NaN` = no search recorded yet.
    pub fn last_certified_gap(&self) -> f64 {
        let bits = self.last_gap_bits.load(Ordering::Relaxed);
        if bits == 0 {
            f64::NAN
        } else {
            f64::from_bits(bits)
        }
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} shed={} queue_depth={} queue_high_water={} queue_wait_max_ns={} opt_batches={} opt_batched_jobs={} max_opt_batch={} exec_batches={} max_batch={} cache_hits={} opt_cache_hits_exact={} opt_cache_hits_canonical={} opt_coalesced={} opt_cache_flushes={} arena_pool_high_water={} search_expanded={} search_generated={} search_pruned={} search_type_rejects={} search_bound_updates={} search_extractions={} search_budget_hits={} search_deadline_hits={} search_cancelled={} cancelled_before_start={} last_gap={} verify_passed={} verify_rejects={} exec_parallel_loops={} exec_serial_fallback={} exec_threads_high_water={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.queue_depth.load(Ordering::Relaxed),
            self.queue_high_water.load(Ordering::Relaxed),
            self.queue_wait_max_ns.load(Ordering::Relaxed),
            self.opt_batches.load(Ordering::Relaxed),
            self.opt_batched_jobs.load(Ordering::Relaxed),
            self.max_opt_batch.load(Ordering::Relaxed),
            self.exec_batches.load(Ordering::Relaxed),
            self.max_batch_seen.load(Ordering::Relaxed),
            self.exec_cache_hits.load(Ordering::Relaxed),
            self.opt_cache_hits_exact.load(Ordering::Relaxed),
            self.opt_cache_hits_canonical.load(Ordering::Relaxed),
            self.opt_coalesced.load(Ordering::Relaxed),
            self.opt_cache_flushes.load(Ordering::Relaxed),
            self.arena_pool_high_water.load(Ordering::Relaxed),
            self.search_expanded.load(Ordering::Relaxed),
            self.search_generated.load(Ordering::Relaxed),
            self.search_pruned.load(Ordering::Relaxed),
            self.search_type_rejects.load(Ordering::Relaxed),
            self.search_bound_updates.load(Ordering::Relaxed),
            self.search_extractions.load(Ordering::Relaxed),
            self.search_budget_hits.load(Ordering::Relaxed),
            self.search_deadline_hits.load(Ordering::Relaxed),
            self.search_cancelled.load(Ordering::Relaxed),
            self.cancelled_before_start.load(Ordering::Relaxed),
            // A gauge, not a counter: "-" until a fresh search records.
            match self.last_certified_gap() {
                g if g.is_nan() => "-".to_string(),
                g => format!("{g:.3}"),
            },
            self.verify_passed.load(Ordering::Relaxed),
            self.verify_rejects.load(Ordering::Relaxed),
            self.exec_parallel_loops.load(Ordering::Relaxed),
            self.exec_serial_fallback.load(Ordering::Relaxed),
            self.exec_threads_high_water.load(Ordering::Relaxed),
        )
    }

    /// Jobs in flight (submitted minus resolved).
    pub fn in_flight(&self) -> u64 {
        self.submitted
            .load(Ordering::Relaxed)
            .saturating_sub(
                self.completed.load(Ordering::Relaxed) + self.failed.load(Ordering::Relaxed),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_and_in_flight() {
        let m = Metrics::default();
        m.submitted.store(5, Ordering::Relaxed);
        m.completed.store(3, Ordering::Relaxed);
        m.failed.store(1, Ordering::Relaxed);
        assert_eq!(m.in_flight(), 1);
        assert!(m.summary().contains("submitted=5"));
    }

    #[test]
    fn record_search_accumulates() {
        let m = Metrics::default();
        let stats = SearchStats {
            expanded: 3,
            generated: 10,
            kept: 6,
            pruned: 2,
            type_rejects: 1,
            bound_updates: 4,
            shards: 2,
            extracted_per_shard: vec![3, 2],
            certified_gap: 1.5,
            min_open_bound: 10.0,
            frontier_open: 2,
            complete: false,
            budget_hit: true,
            deadline_hit: false,
            cancelled: false,
        };
        m.record_search(&stats);
        m.record_search(&stats);
        assert_eq!(m.search_expanded.load(Ordering::Relaxed), 6);
        assert_eq!(m.search_generated.load(Ordering::Relaxed), 20);
        assert_eq!(m.search_pruned.load(Ordering::Relaxed), 4);
        assert_eq!(m.search_type_rejects.load(Ordering::Relaxed), 2);
        assert_eq!(m.search_bound_updates.load(Ordering::Relaxed), 8);
        assert_eq!(m.search_extractions.load(Ordering::Relaxed), 10);
        assert_eq!(m.search_budget_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.search_deadline_hits.load(Ordering::Relaxed), 0);
        assert_eq!(m.last_certified_gap(), 1.5);
        assert!(m.summary().contains("search_pruned=4"));
        assert!(m.summary().contains("search_budget_hits=2"));
        assert!(m.summary().contains("last_gap=1.500"));
    }

    #[test]
    fn gap_gauge_is_dash_until_a_search_records() {
        let m = Metrics::default();
        assert!(m.last_certified_gap().is_nan());
        assert!(m.summary().contains("last_gap=-"));
        let stats = SearchStats {
            certified_gap: 1.0,
            complete: true,
            ..Default::default()
        };
        m.record_search(&stats);
        assert_eq!(m.last_certified_gap(), 1.0);
        assert!(m.summary().contains("last_gap=1.000"));
    }

    #[test]
    fn sharing_counters_sum_and_surface_in_summary() {
        let m = Metrics::default();
        m.opt_cache_hits_exact.store(3, Ordering::Relaxed);
        m.opt_cache_hits_canonical.store(2, Ordering::Relaxed);
        m.opt_coalesced.store(5, Ordering::Relaxed);
        m.arena_pool_high_water.store(4, Ordering::Relaxed);
        assert_eq!(m.opt_cache_hits(), 5);
        let s = m.summary();
        assert!(s.contains("opt_cache_hits_exact=3"));
        assert!(s.contains("opt_cache_hits_canonical=2"));
        assert!(s.contains("opt_coalesced=5"));
        assert!(s.contains("arena_pool_high_water=4"));
    }

    #[test]
    fn service_front_end_counters_surface_in_summary() {
        let m = Metrics::default();
        m.shed.store(3, Ordering::Relaxed);
        m.queue_depth.store(2, Ordering::Relaxed);
        m.queue_high_water.store(7, Ordering::Relaxed);
        m.cancelled_before_start.store(1, Ordering::Relaxed);
        m.record_queue_wait(std::time::Duration::from_micros(5));
        m.record_queue_wait(std::time::Duration::from_micros(2));
        assert_eq!(m.queue_wait_ns_total.load(Ordering::Relaxed), 7_000);
        assert_eq!(m.queue_wait_max_ns.load(Ordering::Relaxed), 5_000);
        // Singleton batches count as batches but not as batched jobs.
        m.record_batch(1);
        m.record_batch(3);
        assert_eq!(m.opt_batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.opt_batched_jobs.load(Ordering::Relaxed), 3);
        assert_eq!(m.max_opt_batch.load(Ordering::Relaxed), 3);
        let stats = SearchStats {
            cancelled: true,
            ..Default::default()
        };
        m.record_search(&stats);
        let s = m.summary();
        assert!(s.contains("shed=3"));
        assert!(s.contains("queue_depth=2"));
        assert!(s.contains("queue_high_water=7"));
        assert!(s.contains("queue_wait_max_ns=5000"));
        assert!(s.contains("opt_batches=2"));
        assert!(s.contains("opt_batched_jobs=3"));
        assert!(s.contains("max_opt_batch=3"));
        assert!(s.contains("search_cancelled=1"));
        assert!(s.contains("cancelled_before_start=1"));
    }

    #[test]
    fn verify_counters_surface_in_summary() {
        let m = Metrics::default();
        m.verify_passed.store(7, Ordering::Relaxed);
        m.verify_rejects.store(1, Ordering::Relaxed);
        assert!(m.summary().contains("verify_passed=7"));
        assert!(m.summary().contains("verify_rejects=1"));
    }

    #[test]
    fn exec_counters_surface_in_summary() {
        let m = Metrics::default();
        m.exec_parallel_loops.store(4, Ordering::Relaxed);
        m.exec_serial_fallback.store(2, Ordering::Relaxed);
        m.exec_threads_high_water.store(8, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("exec_parallel_loops=4"));
        assert!(s.contains("exec_serial_fallback=2"));
        assert!(s.contains("exec_threads_high_water=8"));
    }
}
